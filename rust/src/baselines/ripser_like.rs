//! Ripser-style baseline: combinatorial indexing + heap reduction.
//!
//! Independent of the Dory machinery on purpose: simplices are identified
//! by combinatorial number system indices (`C(v2,3)+C(v1,2)+C(v0,1)`-style
//! u64s — the encoding that overflows on million-point data sets, which is
//! exactly what the paper reports for Ripser on Hi-C), distances come from
//! a dense matrix (`O(n²)` memory, Ripser's compressed lower distance
//! matrix), and columns are reduced with a binary min-heap of cofacets.
//! Persistent cohomology with clearing, dims 0..=2.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::geometry::MetricData;
use crate::homology::diagram::Diagram;

/// Why the baseline could not process a data set (Table 3's NA entries).
#[derive(Debug, Clone, PartialEq)]
pub enum RipserError {
    /// `C(n, k)` exceeded u64 — combinatorial index overflow.
    IndexOverflow,
    /// Dense distance matrix would exceed the memory budget.
    MatrixTooLarge { bytes: usize },
}

pub struct RipserLike {
    n: usize,
    dist: Vec<f32>,
    /// Sorted adjacency per vertex: (neighbor, distance), by neighbor id.
    adj: Vec<Vec<(u32, f32)>>,
    tau: f32,
    binom: Vec<[u64; 5]>,
}

/// Memory budget for the dense matrix (bytes); beyond it we refuse like
/// Ripser effectively did (NA / crash) on the Hi-C data sets.
pub const DEFAULT_MATRIX_BUDGET: usize = 2 << 30;

impl RipserLike {
    pub fn new(data: &MetricData, tau: f64, budget: usize) -> Result<Self, RipserError> {
        let n = data.n();
        let bytes = n.saturating_mul(n).saturating_mul(4);
        if bytes > budget {
            return Err(RipserError::MatrixTooLarge { bytes });
        }
        let mut dist = vec![0f32; n * n];
        match data {
            MetricData::Points(pc) => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        let d = pc.dist(i, j) as f32;
                        dist[i * n + j] = d;
                        dist[j * n + i] = d;
                    }
                }
            }
            MetricData::Dense(dd) => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        let d = dd.get(i, j) as f32;
                        dist[i * n + j] = d;
                        dist[j * n + i] = d;
                    }
                }
            }
            MetricData::Sparse(sd) => {
                // Absent pairs are "infinitely" far: beyond any tau.
                for d in dist.iter_mut() {
                    *d = f32::INFINITY;
                }
                for i in 0..n {
                    dist[i * n + i] = 0.0;
                }
                for &(u, v, d) in &sd.entries {
                    dist[u as usize * n + v as usize] = d as f32;
                    dist[v as usize * n + u as usize] = d as f32;
                }
            }
        }
        let tau = tau as f32;
        let mut adj = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i != j && dist[i * n + j] <= tau {
                    adj[i].push((j as u32, dist[i * n + j]));
                }
            }
        }
        // Binomial table up to C(n, 4); detect u64 overflow (the Ripser
        // failure mode on millions of points).
        let mut binom = vec![[0u64; 5]; n + 1];
        binom[0][0] = 1;
        for i in 1..=n {
            binom[i][0] = 1;
            for k in 1..5 {
                let (a, b) = (binom[i - 1][k - 1], binom[i - 1][k]);
                match a.checked_add(b) {
                    Some(v) => binom[i][k] = v,
                    None => return Err(RipserError::IndexOverflow),
                }
            }
        }
        Ok(Self {
            n,
            dist,
            adj,
            tau,
            binom,
        })
    }

    #[inline]
    fn d(&self, i: u32, j: u32) -> f32 {
        self.dist[i as usize * self.n + j as usize]
    }

    fn b(&self, n: u32, k: usize) -> u64 {
        self.binom[n as usize][k]
    }

    /// Combinatorial index of a triangle (vertices any order).
    fn tri_index(&self, mut v: [u32; 3]) -> u64 {
        v.sort_unstable_by(|a, b| b.cmp(a));
        self.b(v[0], 3) + self.b(v[1], 2) + self.b(v[2], 1)
    }

    fn tet_index(&self, mut v: [u32; 4]) -> u64 {
        v.sort_unstable_by(|a, b| b.cmp(a));
        self.b(v[0], 4) + self.b(v[1], 3) + self.b(v[2], 2) + self.b(v[3], 1)
    }

    /// Compute PD up to `max_dim` (0..=2).
    pub fn compute(&self, max_dim: usize) -> Diagram {
        let mut diagram = Diagram::new(max_dim);

        // ---- H0: union-find ---------------------------------------------
        let mut edges: Vec<(f32, u32, u32)> = Vec::new();
        for i in 0..self.n as u32 {
            for &(j, d) in &self.adj[i as usize] {
                if j > i {
                    edges.push((d, i, j));
                }
            }
        }
        edges.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut parent: Vec<u32> = (0..self.n as u32).collect();
        fn find(parent: &mut [u32], mut x: u32) -> u32 {
            while parent[x as usize] != x {
                let g = parent[parent[x as usize] as usize];
                parent[x as usize] = g;
                x = g;
            }
            x
        }
        let mut negative = vec![false; edges.len()];
        for (idx, &(d, a, b)) in edges.iter().enumerate() {
            let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
            if ra != rb {
                parent[ra as usize] = rb;
                negative[idx] = true;
                diagram.push(0, 0.0, d as f64);
            }
        }
        let comps = (0..self.n as u32)
            .filter(|&v| find(&mut parent, v) == v)
            .count();
        for _ in 0..comps {
            diagram.push(0, 0.0, f64::INFINITY);
        }
        if max_dim == 0 {
            return diagram;
        }

        // ---- H1: cohomology over edge columns ---------------------------
        // Columns: positive edges, decreasing (diam, index) order.
        let mut cols: Vec<(f32, u32, u32)> = edges
            .iter()
            .zip(&negative)
            .filter(|(_, &neg)| !neg)
            .map(|(&e, _)| e)
            .collect();
        cols.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // pivot (tri index) -> position in `cols` of owner + its ops.
        let mut pivot_owner: HashMap<u64, usize> = HashMap::new();
        let mut ops: Vec<Vec<usize>> = vec![Vec::new(); cols.len()];
        let mut tri_pivots: HashMap<u64, f32> = HashMap::new(); // for dim-2 clearing

        for ci in 0..cols.len() {
            // Working column: min-heap of cofacet (diam, index).
            let mut heap: BinaryHeap<Reverse<(NotNanF32, u64)>> = BinaryHeap::new();
            let mut members: Vec<usize> = vec![ci];
            self.push_edge_cofacets(cols[ci], &mut heap);
            let pivot = loop {
                // Pop pairs until an odd survivor.
                let top = match heap.pop() {
                    Some(Reverse(t)) => t,
                    None => break None,
                };
                if heap.peek() == Some(&Reverse(top)) {
                    heap.pop();
                    continue;
                }
                // Survivor: is it claimed?
                if let Some(&owner) = pivot_owner.get(&top.1) {
                    // Add owner column (its edge cofacets and its ops').
                    heap.push(Reverse(top)); // keep; owner's pivot cancels it
                    self.push_edge_cofacets(cols[owner], &mut heap);
                    members.push(owner);
                    for &op in ops[owner].clone().iter() {
                        self.push_edge_cofacets(cols[op], &mut heap);
                        members.push(op);
                    }
                    continue;
                }
                break Some(top);
            };
            if let Some((diam, idx)) = pivot {
                pivot_owner.insert(idx, ci);
                tri_pivots.insert(idx, diam.0);
                // Record ops (columns other than self, odd multiplicity).
                let mut counts: HashMap<usize, u32> = HashMap::new();
                for &m in &members {
                    *counts.entry(m).or_insert(0) += 1;
                }
                ops[ci] = counts
                    .into_iter()
                    .filter(|&(m, c)| m != ci && c % 2 == 1)
                    .map(|(m, _)| m)
                    .collect();
                diagram.push(1, cols[ci].0 as f64, diam.0 as f64);
            } else {
                diagram.push(1, cols[ci].0 as f64, f64::INFINITY);
            }
        }
        if max_dim == 1 {
            return diagram;
        }

        // ---- H2: cohomology over triangle columns -----------------------
        // Enumerate triangles once, attributed to their diameter edge
        // (ties by vertex order) to avoid duplicates.
        let mut tris: Vec<(f32, u32, u32, u32)> = Vec::new();
        for &(d_ab, a, b) in &edges {
            // Common neighbors with both connecting distances <= d_ab
            // (with deterministic tie attribution via index comparison).
            let (la, lb) = (&self.adj[a as usize], &self.adj[b as usize]);
            let (mut x, mut y) = (0, 0);
            while x < la.len() && y < lb.len() {
                match la[x].0.cmp(&lb[y].0) {
                    std::cmp::Ordering::Less => x += 1,
                    std::cmp::Ordering::Greater => y += 1,
                    std::cmp::Ordering::Equal => {
                        let v = la[x].0;
                        let (dav, dbv) = (la[x].1, lb[y].1);
                        // {a,b} is THE diameter edge iff it is the largest
                        // by (distance, endpoints) among the three.
                        let key_ab = edge_key(d_ab, a, b);
                        if edge_key(dav, a.min(v), a.max(v)) < key_ab
                            && edge_key(dbv, b.min(v), b.max(v)) < key_ab
                        {
                            tris.push((d_ab, a, b, v));
                        }
                        x += 1;
                        y += 1;
                    }
                }
            }
        }
        // Clearing: drop triangles that are dim-1 pivots; sort desc.
        tris.retain(|&(_, a, b, v)| !tri_pivots.contains_key(&self.tri_index([a, b, v])));
        tris.sort_by(|p, q| {
            let kp = (p.0, self.tri_index([p.1, p.2, p.3]));
            let kq = (q.0, self.tri_index([q.1, q.2, q.3]));
            kq.partial_cmp(&kp).unwrap()
        });
        let mut pivot_owner2: HashMap<u64, usize> = HashMap::new();
        let mut ops2: Vec<Vec<usize>> = vec![Vec::new(); tris.len()];
        for ci in 0..tris.len() {
            let mut heap: BinaryHeap<Reverse<(NotNanF32, u64)>> = BinaryHeap::new();
            let mut members: Vec<usize> = vec![ci];
            self.push_tri_cofacets(tris[ci], &mut heap);
            let pivot = loop {
                let top = match heap.pop() {
                    Some(Reverse(t)) => t,
                    None => break None,
                };
                if heap.peek() == Some(&Reverse(top)) {
                    heap.pop();
                    continue;
                }
                if let Some(&owner) = pivot_owner2.get(&top.1) {
                    heap.push(Reverse(top));
                    self.push_tri_cofacets(tris[owner], &mut heap);
                    members.push(owner);
                    for &op in ops2[owner].clone().iter() {
                        self.push_tri_cofacets(tris[op], &mut heap);
                        members.push(op);
                    }
                    continue;
                }
                break Some(top);
            };
            if let Some((diam, idx)) = pivot {
                pivot_owner2.insert(idx, ci);
                let mut counts: HashMap<usize, u32> = HashMap::new();
                for &m in &members {
                    *counts.entry(m).or_insert(0) += 1;
                }
                ops2[ci] = counts
                    .into_iter()
                    .filter(|&(m, c)| m != ci && c % 2 == 1)
                    .map(|(m, _)| m)
                    .collect();
                diagram.push(2, tris[ci].0 as f64, diam.0 as f64);
            } else {
                diagram.push(2, tris[ci].0 as f64, f64::INFINITY);
            }
        }
        diagram
    }

    fn push_edge_cofacets(
        &self,
        (d_ab, a, b): (f32, u32, u32),
        heap: &mut BinaryHeap<Reverse<(NotNanF32, u64)>>,
    ) {
        let (la, lb) = (&self.adj[a as usize], &self.adj[b as usize]);
        let (mut x, mut y) = (0, 0);
        while x < la.len() && y < lb.len() {
            match la[x].0.cmp(&lb[y].0) {
                std::cmp::Ordering::Less => x += 1,
                std::cmp::Ordering::Greater => y += 1,
                std::cmp::Ordering::Equal => {
                    let v = la[x].0;
                    let diam = d_ab.max(la[x].1).max(lb[y].1);
                    if diam <= self.tau {
                        heap.push(Reverse((NotNanF32(diam), self.tri_index([a, b, v]))));
                    }
                    x += 1;
                    y += 1;
                }
            }
        }
    }

    fn push_tri_cofacets(
        &self,
        (d_t, a, b, c): (f32, u32, u32, u32),
        heap: &mut BinaryHeap<Reverse<(NotNanF32, u64)>>,
    ) {
        // Common neighbors of a, b, c via the smallest adjacency list.
        let la = &self.adj[a as usize];
        for &(v, dav) in la {
            if v == b || v == c {
                continue;
            }
            let (dbv, dcv) = (self.d(b, v), self.d(c, v));
            if dbv <= self.tau && dcv <= self.tau {
                let diam = d_t.max(dav).max(dbv).max(dcv);
                if diam <= self.tau {
                    heap.push(Reverse((NotNanF32(diam), self.tet_index([a, b, c, v]))));
                }
            }
        }
    }
}

/// Deterministic total order on edges: (distance, a, b).
fn edge_key(d: f32, a: u32, b: u32) -> (NotNanF32, u32, u32) {
    (NotNanF32(d), a, b)
}

/// f32 wrapper with total order (no NaNs by construction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NotNanF32(pub f32);
impl Eq for NotNanF32 {}
impl PartialOrd for NotNanF32 {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for NotNanF32 {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&o.0).expect("NaN distance")
    }
}

/// Convenience wrapper: full run, Table-3 style.
pub fn compute_ph(
    data: &MetricData,
    tau: f64,
    max_dim: usize,
    budget: usize,
) -> Result<Diagram, RipserError> {
    Ok(RipserLike::new(data, tau, budget)?.compute(max_dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::geometry::PointCloud;

    #[test]
    fn matches_dory_on_random_clouds() {
        use crate::homology::{compute_ph as dory_ph, EngineOptions};
        for seed in 0..6 {
            let data = datasets::random_cloud(20, 3, seed);
            let want = dory_ph(&data, 0.8, &EngineOptions::default()).diagram;
            let got = compute_ph(&data, 0.8, 2, usize::MAX).unwrap();
            // f32 matrix: compare with loose tolerance.
            assert!(
                got.multiset_eq(&want, 1e-5),
                "seed={seed}:\n{}",
                got.diff_summary(&want)
            );
        }
    }

    #[test]
    fn circle_loop() {
        let data = datasets::circle(30, 1.0, 0.0, 1);
        let d = compute_ph(&data, 3.0, 1, usize::MAX).unwrap();
        assert_eq!(d.significant(1, 0.5).len(), 1);
        assert_eq!(d.essential_count(0), 1);
    }

    #[test]
    fn refuses_oversized_matrix() {
        let data = datasets::random_cloud(100, 2, 1);
        let err = compute_ph(&data, 1.0, 1, 1024).unwrap_err();
        assert!(matches!(err, RipserError::MatrixTooLarge { .. }));
    }

    #[test]
    fn sparse_input_handled() {
        use crate::geometry::{MetricData, SparseDistances};
        // A 4-cycle given as a sparse distance list: one loop.
        let entries = vec![
            (0u32, 1u32, 1.0),
            (1, 2, 1.0),
            (2, 3, 1.0),
            (0, 3, 1.0),
            (0, 2, 1.6),
            (1, 3, 1.6),
        ];
        let data = MetricData::Sparse(SparseDistances { n: 4, entries });
        let d = compute_ph(&data, 1.2, 1, usize::MAX).unwrap();
        assert_eq!(d.essential_count(1), 1, "open loop at tau=1.2");
    }

    #[test]
    fn tri_index_unique() {
        let pc = PointCloud::new(1, (0..10).map(|i| i as f64).collect());
        let data = crate::geometry::MetricData::Points(pc);
        let r = RipserLike::new(&data, 100.0, usize::MAX).unwrap();
        let mut seen = std::collections::HashSet::new();
        for a in 0..10u32 {
            for b in (a + 1)..10 {
                for c in (b + 1)..10 {
                    assert!(seen.insert(r.tri_index([a, b, c])));
                    assert_eq!(r.tri_index([a, b, c]), r.tri_index([c, a, b]));
                }
            }
        }
    }
}
