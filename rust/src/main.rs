//! `dory` — CLI launcher for the persistent-homology engine.
//!
//! Subcommands:
//!   run       compute PH (flags or --config TOML; repeat --tau for a
//!             multi-query batch served from one ingest)
//!   serve     multi-tenant JSON-RPC loop over stdio (one request per
//!             line; see `dory::serve` for the wire protocol)
//!   generate  export a synthetic dataset to disk
//!   info      show PJRT platform + artifact inventory
//!   help      this text
//!
//! Examples:
//!   dory run --dataset torus4 --n 8000 --tau 0.2 --dim 2 --threads 4 \
//!            --pd out/pd.csv --summary out/summary.json
//!   dory run --dataset sphere --n 1000 --tau 0.4 --tau 0.6 --tau 0.8 \
//!            --summary out/batch.json
//!   dory run --config configs/hic_control.toml
//!   dory generate --dataset hic --n 20000 --condition auxin --out hic_auxin.coo
//!   dory info
//!
//! Failures surface as typed `DoryError`s: one `error:` line and a
//! nonzero exit code, never a panic backtrace.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};
use dory::coordinator::{self, DatasetSpec, QuerySpec, RunConfig};
use dory::util::memtrack;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("generate") => cmd_generate(&args[1..]),
        Some("info") => cmd_info(),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}` (try `dory help`)"),
    }
}

const HELP: &str = "\
dory — scalable persistent homology (Aggarwal & Periwal 2021 reproduction)

USAGE: dory <run|generate|info|help> [flags]

run flags:
  --config <file.toml>      load a full run config (other flags override;
                            a [[query]] array runs a multi-query batch)
  --dataset <kind>          circle|figure-eight|sphere|torus3|torus4|o3|
                            dragon|fractal|random|multi-scale|hic
  --points <file>           load a point cloud instead
  --lower-distance <file>   load a lower-triangular distance matrix
  --sparse <file>           load a sparse `i j d` distance list
  --n <int>                 dataset size            [200]
  --seed <int>              dataset RNG seed        [1]
  --condition <c>           hic: control|auxin      [control]
  --tau <float|inf>         filtration threshold    [inf]; repeat the
                            flag to serve several thresholds from ONE
                            ingest (session batch; replaces any config
                            [[query]] array)
  --dim <0|1|2>             max homology dimension  [2]
  --threads <int>           worker threads          [4]
  --batch <int>             serial-parallel batch   [100]
  --fixed-batch             disable adaptive batch sizing
  --batch-min <int>         adaptive batch lower bound  [16]
  --batch-max <int>         adaptive batch upper bound  [8192]
  --steal-grain <int>       columns per steal task (0 = auto)
  --adapt-low <float>       serial fraction below which batch doubles [0.25]
  --adapt-high <float>      serial fraction above which batch halves  [0.75]
  --enum-shards <int>       H1*/H2* enumeration shards (0 = auto)
  --enum-grain <int>        diameter edges per enumeration shard (0 = auto)
  --no-shortcut             disable the enumeration-time apparent-pair
                            shortcut (exact fallback; on by default)
  --f1-tile <int>           point rows per front-end distance tile (0 = auto)
  --simd <mode>             distance microkernel: auto|scalar|avx2|neon
                            [auto]; forced vector modes fall back to
                            scalar when the CPU lacks the feature, and
                            every mode emits bit-identical edges
  --stream-chunk <int>      stream-ingest --sparse files, parsing this
                            many lines per chunk (0 = off; default
                            65536-line chunks when only the budget is set)
  --edge-budget-mb <int>    spill sorted edge-key runs to disk past this
                            staging budget and k-way merge them back
                            (0 = off; implies streaming for --sparse and
                            routes dense point clouds / distance tables
                            through the spill store, edge_source
                            dense-stream, bit-identical output)
  --knn-k <int>             sparse net-graph front-end for point clouds:
                            keep the k nearest incident edges per vertex
                            (0 = off/exact; diagrams 2eps-stable in the
                            net radius)
  --strict-spill            refuse the in-memory fallback when spill
                            writes keep failing (typed I/O error instead
                            of degraded unbounded staging)
  --timeout-ms <int>        per-query deadline in milliseconds; an
                            expired query aborts with a typed
                            DeadlineExceeded (default: none)
  --features <list>         comma-separated derived feature products
                            computed post-reduction for every query:
                            betti[:GRID], entropy, landscape[:K[:GRID]],
                            image[:GRID], representatives[:MIN_PERS]
                            (e.g. --features betti:64,entropy,image:32;
                            results land in the summary's queries array)
  --no-enclosing            disable the enclosing-radius truncation of
                            infinite-tau filtrations (exact fallback;
                            on by default, diagrams unchanged either way)
  --ns                      DoryNS dense edge-order lookup
  --algorithm <a>           fast-column|implicit-row
  --no-pjrt                 skip the PJRT/Pallas distance kernel
  --pimage                  also compute a persistence image (PJRT)
  --pd <file.csv>           write the persistence diagram (CSV; batch
                            runs write one file per query, pd.qN.csv)
  --pd-json <file.json>     write the persistence diagram (JSON)
  --summary <file.json>     write the machine-readable run summary (one
                            file; batch runs add a `queries` array)

serve flags:
  --threads <int>           worker threads shared by all tenants [4]
  --dim <0|1|2>             default max homology dimension       [2]
  --no-shortcut             default the apparent-pair shortcut off
  --cache-mb <int>          handle-cache byte budget in MiB      [256]
  --data-root <dir>         confine {"path":...} wire ingests to files
                            under this directory (default: any path
                            readable by the server process)
  --max-inflight <int>      admit at most this many query/batch/ingest
                            requests at once; excess is shed with a
                            typed Overloaded error (0 = unbounded [0])
  --tenant-quota <int>      per-tenant in-flight cap (0 = unbounded [0])
  --strict-spill            refuse degraded in-memory staging on wire
                            ingests whose spill writes keep failing
  --max-diagram-points <n>  refuse {"diagram":true} query payloads whose
                            PD exceeds this many points with a typed
                            Request error (0 = unbounded [0])
  Reads one JSON request per line on stdin, writes one JSON response
  per line on stdout; EOF or a {\"method\":\"shutdown\"} request ends the
  loop with a {\"summary\":...} trailer (per-tenant counters, cache and
  session stats, peak RSS). See the `dory::serve` module docs for the
  ingest/query/batch wire schema.

generate flags:
  --dataset <kind> --n <int> --seed <int> [--condition control|auxin]
  --out <file>              points file (.xyz) or sparse list for hic
";

fn cmd_run(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    // --config first, so flags can override it.
    if let Some(pos) = args.iter().position(|a| a == "--config") {
        let path = args.get(pos + 1).context("--config needs a path")?;
        cfg = RunConfig::from_file(&PathBuf::from(path))?;
    }
    let mut kind: Option<String> = None;
    let mut n: Option<usize> = None;
    let mut seed: Option<u64> = None;
    let mut condition: Option<String> = None;
    let mut taus: Vec<f64> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut val = || -> Result<&String> {
            it.next().with_context(|| format!("{a} needs a value"))
        };
        match a.as_str() {
            "--config" => {
                val()?;
            }
            "--dataset" => kind = Some(val()?.clone()),
            "--points" => {
                let p = PathBuf::from(val()?);
                cfg.dataset = DatasetSpec::PointsFile(p);
            }
            "--lower-distance" => {
                let p = PathBuf::from(val()?);
                cfg.dataset = DatasetSpec::LowerDistanceFile(p);
            }
            "--sparse" => {
                let p = PathBuf::from(val()?);
                cfg.dataset = DatasetSpec::SparseFile(p);
            }
            "--n" => n = Some(val()?.parse()?),
            "--seed" => seed = Some(val()?.parse()?),
            "--condition" => condition = Some(val()?.clone()),
            "--tau" => {
                let v = val()?;
                let t = if v == "inf" { f64::INFINITY } else { v.parse()? };
                // `"NaN".parse::<f64>()` succeeds, and a NaN (or negative)
                // τ would silently serve an empty diagram downstream.
                if t.is_nan() || t < 0.0 {
                    bail!("--tau must be a non-negative number or `inf`, got {v}");
                }
                taus.push(t);
            }
            "--dim" => cfg.max_dim = val()?.parse()?,
            "--threads" => cfg.threads = val()?.parse()?,
            "--batch" => cfg.batch_size = val()?.parse()?,
            "--fixed-batch" => cfg.adaptive_batch = false,
            "--batch-min" => cfg.batch_min = val()?.parse()?,
            "--batch-max" => cfg.batch_max = val()?.parse()?,
            "--steal-grain" => cfg.steal_grain = val()?.parse()?,
            "--adapt-low" => cfg.adapt_low = val()?.parse()?,
            "--adapt-high" => cfg.adapt_high = val()?.parse()?,
            "--enum-shards" => cfg.enum_shards = val()?.parse()?,
            "--enum-grain" => cfg.enum_grain = val()?.parse()?,
            "--no-shortcut" => cfg.shortcut = false,
            "--f1-tile" => cfg.f1_tile = val()?.parse()?,
            "--simd" => cfg.simd = val()?.clone(),
            "--stream-chunk" => cfg.stream_chunk = val()?.parse()?,
            "--edge-budget-mb" => cfg.edge_budget_mb = val()?.parse()?,
            "--knn-k" => cfg.knn_k = val()?.parse()?,
            "--strict-spill" => cfg.strict_spill = true,
            "--timeout-ms" => cfg.timeout_ms = Some(val()?.parse()?),
            "--features" => {
                cfg.features = dory::features::FeatureSpec::parse_list(val()?)
                    .map_err(|e| anyhow::anyhow!("--features: {e}"))?;
            }
            "--no-enclosing" => cfg.enclosing = false,
            "--ns" => cfg.dense_lookup = true,
            "--algorithm" => cfg.algorithm = val()?.clone(),
            "--no-pjrt" => cfg.use_pjrt = false,
            "--pimage" => cfg.pimage = true,
            "--pd" => {
                let p = PathBuf::from(val()?);
                cfg.diagram_csv = Some(p);
            }
            "--pd-json" => {
                let p = PathBuf::from(val()?);
                cfg.diagram_json = Some(p);
            }
            "--summary" => {
                let p = PathBuf::from(val()?);
                cfg.summary_json = Some(p);
            }
            other => bail!("unknown flag {other}"),
        }
    }
    if kind.is_some() || n.is_some() || seed.is_some() || condition.is_some() {
        let kind = kind.unwrap_or_else(|| "circle".into());
        let n = n.unwrap_or(200);
        let seed = seed.unwrap_or(1);
        cfg.dataset = if kind == "hic" {
            DatasetSpec::Hic {
                n_bins: n,
                condition: condition.unwrap_or_else(|| "control".into()),
                seed,
            }
        } else {
            DatasetSpec::Named { kind, n, seed }
        };
    }
    // Repeated --tau flags define the query batch (replacing any config
    // [[query]] array); a single --tau keeps the legacy one-shot shape.
    match taus.len() {
        0 => {}
        1 => {
            cfg.tau = taus[0];
            cfg.queries.clear();
        }
        _ => {
            cfg.tau = taus.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            cfg.queries = taus.iter().map(|&t| QuerySpec::at(t)).collect();
        }
    }
    cfg.validate()?;

    let t0 = std::time::Instant::now();
    let report = coordinator::run_batch(&cfg)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "n={} ingest edges={} via {} | {} queries on 1 ingest | total {:.2}s | peak heap {} (rss {})",
        report.n_points,
        report.ingest_edges,
        report.edge_source,
        report.responses.len(),
        dt,
        memtrack::fmt_bytes(report.peak_heap_bytes),
        memtrack::fmt_bytes(memtrack::max_rss_bytes()),
    );
    let fs = &report.ingest_stats;
    if fs.edges_considered > 0 {
        let pruned = if fs.edges_pruned > 0 {
            format!(
                ", {} pruned at r_enc={:.6}",
                fs.edges_pruned, fs.enclosing_radius
            )
        } else {
            String::new()
        };
        let kernel = if fs.dist_kernel.is_empty() {
            String::new()
        } else {
            format!(" [{}]", fs.dist_kernel)
        };
        let spill = if fs.dense_spilled_runs > 0 {
            format!(
                " | spilled {} runs ({})",
                fs.dense_spilled_runs,
                memtrack::fmt_bytes(fs.dense_spilled_bytes as usize)
            )
        } else {
            String::new()
        };
        println!(
            "front-end: dist {:.3}s{} ({} tiles) | sort {:.3}s ({} chunks) | nbhd {:.3}s ({} chunks) | {} kept of {} considered{}{}",
            fs.dist_ns as f64 * 1e-9,
            kernel,
            fs.tiles,
            fs.sort_ns as f64 * 1e-9,
            fs.sort_chunks,
            fs.nb_ns as f64 * 1e-9,
            fs.nb_chunks,
            fs.edges_kept,
            fs.edges_considered,
            pruned,
            spill,
        );
    }
    let multi = report.responses.len() > 1;
    for (i, resp) in report.responses.iter().enumerate() {
        let d = &resp.result.diagram;
        let st = &resp.result.stats;
        if multi {
            let label = resp
                .label
                .as_deref()
                .map(|l| format!(" ({l})"))
                .unwrap_or_default();
            let served = if resp.truncated {
                format!("prefix of {} edges", resp.n_edges)
            } else {
                "full ingest".to_string()
            };
            println!("query {i}{label}: tau={} | {served}", resp.tau);
        }
        println!("phases: {}", resp.result.timings.summary());
        let rss = resp.result.timings.rss_summary();
        if !rss.is_empty() && !multi {
            println!("phase max-RSS: {rss}");
        }
        let skipped = st.h1.shortcut_pairs + st.h2.shortcut_pairs;
        if skipped > 0 {
            println!(
                "shortcut: {skipped} apparent pairs resolved at enumeration (H1* {:.0}% of {} candidates, H2* {:.0}% of {})",
                st.h1.skip_rate() * 100.0,
                st.h1.columns + st.h1.shortcut_pairs,
                st.h2.skip_rate() * 100.0,
                st.h2.columns + st.h2.shortcut_pairs,
            );
        }
        if cfg.threads > 1 {
            let s = st.sched_total();
            if s.batches > 0 {
                println!("scheduler: {}", s.summary());
            }
        }
        for dim in 0..=d.max_dim() {
            println!(
                "H{dim}: {} finite pairs, {} essential",
                d.finite(dim).len(),
                d.essential_count(dim)
            );
        }
        if let Some(fo) = &resp.features {
            println!(
                "features: {} specs over span {:.6} in {:.3}s ({} points, {} clamped essential, {} cycles)",
                fo.stats.specs,
                fo.span,
                fo.stats.feature_ns as f64 * 1e-9,
                fo.stats.diagram_points,
                fo.stats.clamped_points,
                fo.stats.cycles,
            );
        }
    }
    if multi {
        let s = &report.session;
        println!(
            "session: {} queries served from {} ingest ({} truncated, {} full); builds: F1 {}, CSR {}",
            s.queries, s.ingests, s.truncated_queries, s.full_queries,
            s.filtration_builds, s.nb_builds,
        );
    }
    if let Some((g, img)) = &report.pimage {
        let mx = img.iter().cloned().fold(0.0f32, f32::max);
        println!("persistence image: {g}x{g}, max intensity {mx:.4}");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut threads = 4usize;
    let mut max_dim = 2usize;
    let mut shortcut = true;
    let mut cache_mb = 256usize;
    let mut data_root: Option<std::path::PathBuf> = None;
    let mut max_inflight = 0usize;
    let mut tenant_quota = 0usize;
    let mut strict_spill = false;
    let mut max_diagram_points = 0usize;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut val = || it.next().with_context(|| format!("{a} needs a value"));
        match a.as_str() {
            "--threads" => threads = val()?.parse()?,
            "--dim" => max_dim = val()?.parse()?,
            "--no-shortcut" => shortcut = false,
            "--cache-mb" => cache_mb = val()?.parse()?,
            "--data-root" => data_root = Some(val()?.into()),
            "--max-inflight" => max_inflight = val()?.parse()?,
            "--tenant-quota" => tenant_quota = val()?.parse()?,
            "--strict-spill" => strict_spill = true,
            "--max-diagram-points" => max_diagram_points = val()?.parse()?,
            other => bail!("unknown flag {other}"),
        }
    }
    if max_dim > 2 {
        bail!("--dim must be 0, 1 or 2 (paper scope)");
    }
    let cache_bytes = cache_mb
        .checked_mul(1 << 20)
        .with_context(|| format!("--cache-mb {cache_mb} overflows the byte budget"))?;
    let opts = dory::homology::EngineOptions {
        max_dim,
        threads,
        shortcut,
        ..Default::default()
    };
    let mut server = dory::serve::Server::new(opts, cache_bytes)
        .with_overload(max_inflight, tenant_quota)
        .with_strict_spill(strict_spill)
        .with_max_diagram_points(max_diagram_points);
    if let Some(root) = data_root {
        server = server.with_data_root(root);
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let served = server.serve(stdin.lock(), stdout.lock())?;
    eprintln!("served {served} requests");
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let mut kind = String::from("circle");
    let mut n = 1000usize;
    let mut seed = 1u64;
    let mut condition = String::from("control");
    let mut out: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut val = || it.next().with_context(|| format!("{a} needs a value"));
        match a.as_str() {
            "--dataset" => kind = val()?.clone(),
            "--n" => n = val()?.parse()?,
            "--seed" => seed = val()?.parse()?,
            "--condition" => condition = val()?.clone(),
            "--out" => out = Some(PathBuf::from(val()?)),
            other => bail!("unknown flag {other}"),
        }
    }
    let out = out.context("--out required")?;
    let spec = if kind == "hic" {
        DatasetSpec::Hic {
            n_bins: n,
            condition,
            seed,
        }
    } else {
        DatasetSpec::Named { kind, n, seed }
    };
    match coordinator::build_dataset(&spec)? {
        dory::geometry::MetricData::Points(pc) => dory::io::write_points(&out, &pc)?,
        dory::geometry::MetricData::Sparse(sd) => dory::io::write_sparse_coo(&out, &sd)?,
        dory::geometry::MetricData::Dense(dd) => {
            // Export dense matrices as sparse COO for portability.
            let mut entries = Vec::new();
            for i in 0..dd.n {
                for j in (i + 1)..dd.n {
                    entries.push((i as u32, j as u32, dd.get(i, j)));
                }
            }
            dory::io::write_sparse_coo(
                &out,
                &dory::geometry::SparseDistances { n: dd.n, entries },
            )?;
        }
    }
    println!("wrote {out:?}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = dory::runtime::default_artifact_dir();
    println!("artifact dir: {dir:?}");
    match dory::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("distance kernels: {:?}", rt.dist_shapes());
            println!("persistence-image kernel: {}", rt.has_pimage_kernel());
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    Ok(())
}
