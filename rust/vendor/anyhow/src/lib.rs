//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline vendor set has no registry access, so this crate provides
//! the small slice of the `anyhow` API the workspace actually uses:
//!
//! * [`Error`] — a context-chained error value (`{e}` prints the
//!   outermost message, `{e:#}` the full `outer: ...: root` chain);
//! * [`Result`] with a defaulted error type;
//! * the [`Context`] extension trait on `Result` and `Option`;
//! * the [`anyhow!`] and [`bail!`] macros.
//!
//! Deliberately NOT implemented: downcasting, backtraces, `std::error::
//! Error` for [`Error`] (omitting it is what makes the blanket `From`
//! impl coherent, exactly as in the real crate).

use std::fmt;

/// A context-chained error. `stack[0]` is the root cause; later entries
/// are contexts added around it, outermost last.
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            stack: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.stack.push(c.to_string());
        self
    }

    /// Messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().rev().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        &self.stack[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain: "outer: inner: root".
            for (i, m) in self.stack.iter().rev().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(self.stack.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

/// Any std error converts, capturing its `source()` chain as context.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        // Root cause first, outermost message last.
        msgs.reverse();
        Error { stack: msgs }
    }
}

/// `anyhow::Result<T>` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("not a number")?;
        Ok(v)
    }

    #[test]
    fn context_chains_render() {
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "not a number");
        let full = format!("{e:#}");
        assert!(full.starts_with("not a number: "), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn bail_and_anyhow() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "fell through");
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::fs::read_to_string("/definitely/not/here")
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
    }
}
