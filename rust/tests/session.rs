//! Session-API exactness and typed-error harness.
//!
//! The service contract under test:
//!
//! * **N-query session ≡ N independent fresh runs, bit for bit** — a
//!   batch of τ-queries served from one ingest produces diagrams whose
//!   (dim, birth-bits, death-bits) sequences equal independent
//!   `compute_ph` runs at the same τ and options, swept over τ prefixes
//!   × threads × shortcut/enclosing overrides;
//! * **one build** — the session's `filtration_builds`/`nb_builds`
//!   counters (and the handle's `FiltrationStats`) prove the filtration
//!   and the `Neighborhoods` CSR were built exactly once for the whole
//!   batch;
//! * **typed errors** — NaN ingest, the DoryNS overflow guard, bad
//!   TOML, and out-of-capacity τ requests surface as the matching
//!   `DoryError` variants, never as panics.

use dory::coordinator::{self, DatasetSpec, QuerySpec, RunConfig};
use dory::error::DoryError;
use dory::filtration::{EdgeFiltration, FiltrationStats};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph, EngineOptions, PhRequest, Session};
use dory::util::rng::Pcg32;
use dory::util::timer::PhaseTimer;

fn cloud(n: usize, dim: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

/// The exact byte content of a diagram, in emission order.
fn diagram_bits(d: &dory::homology::Diagram) -> Vec<(usize, u64, u64)> {
    let mut out = Vec::new();
    for dim in 0..=d.max_dim() {
        for p in d.points(dim) {
            out.push((dim, p.birth.to_bits(), p.death.to_bits()));
        }
    }
    out
}

/// Pair/essential/trivial counts per dimension — the structural echo of
/// the diagram comparison.
fn pair_counts(r: &dory::homology::PhResult) -> [(usize, usize, usize); 2] {
    [
        (r.stats.h1.pairs, r.stats.h1.essential, r.stats.h1.trivial_pairs),
        (r.stats.h2.pairs, r.stats.h2.essential, r.stats.h2.trivial_pairs),
    ]
}

#[test]
fn eight_query_session_is_bit_identical_to_eight_fresh_runs() {
    // The acceptance pin: 8 τ-queries on one ingest vs 8 independent
    // compute_ph runs, swept over threads × shortcut, with the build
    // counters proving one filtration + one CSR build per session.
    let data = cloud(30, 3, 2024);
    let tau_ingest = 0.95;
    let taus = [0.25, 0.35, 0.45, 0.55, 0.65, 0.75, 0.85, 0.95];
    for threads in [1usize, 4] {
        for shortcut in [true, false] {
            let opts = EngineOptions {
                max_dim: 2,
                threads,
                shortcut,
                ..Default::default()
            };
            let session = Session::new(opts.clone());
            let handle = session.ingest(&data, tau_ingest).unwrap();
            assert_eq!(handle.stats().f1_builds, 1);
            assert_eq!(handle.stats().nb_builds, 1);
            let reqs: Vec<PhRequest> = taus.iter().map(|&t| PhRequest::at(t)).collect();
            let responses = session.run_batch(&handle, &reqs).unwrap();
            assert_eq!(responses.len(), taus.len());
            for (resp, &tau) in responses.iter().zip(&taus) {
                let fresh = compute_ph(&data, tau, &opts);
                assert_eq!(
                    diagram_bits(&resp.result.diagram),
                    diagram_bits(&fresh.diagram),
                    "threads={threads} shortcut={shortcut} tau={tau}: diagram bytes deviate"
                );
                assert_eq!(
                    pair_counts(&resp.result),
                    pair_counts(&fresh),
                    "threads={threads} shortcut={shortcut} tau={tau}: pair counts deviate"
                );
                assert_eq!(
                    resp.n_edges,
                    fresh.stats.n_edges,
                    "threads={threads} shortcut={shortcut} tau={tau}: served edge count deviates"
                );
                // Responses carry the SHARED ingest's front-end report:
                // still the one build, never a fresh one per query.
                assert_eq!(resp.result.stats.filtration.f1_builds, 1);
                assert_eq!(resp.result.stats.filtration.nb_builds, 1);
            }
            // The filtration and Neighborhoods were built exactly once.
            let st = session.stats();
            assert_eq!(st.ingests, 1, "threads={threads} shortcut={shortcut}");
            assert_eq!(st.filtration_builds, 1, "threads={threads} shortcut={shortcut}");
            assert_eq!(st.nb_builds, 1, "threads={threads} shortcut={shortcut}");
            assert_eq!(st.queries, taus.len() as u64);
            assert_eq!(st.truncated_queries, taus.len() as u64 - 1);
            assert_eq!(st.full_queries, 1);
        }
    }
}

#[test]
fn dense_lookup_session_matches_fresh_runs() {
    // DoryNS handles: the dense edge-order table is part of the shared
    // build; truncated views must filter it exactly like a rebuilt one.
    let data = cloud(24, 3, 7);
    let opts = EngineOptions {
        max_dim: 2,
        threads: 2,
        dense_lookup: true,
        ..Default::default()
    };
    let session = Session::new(opts.clone());
    let handle = session.ingest(&data, 0.9).unwrap();
    for tau in [0.3, 0.6, 0.9] {
        let resp = session.query(&handle, &PhRequest::at(tau)).unwrap();
        let fresh = compute_ph(&data, tau, &opts);
        assert_eq!(
            diagram_bits(&resp.result.diagram),
            diagram_bits(&fresh.diagram),
            "dense tau={tau}"
        );
    }
    assert_eq!(session.stats().nb_builds, 1);
}

#[test]
fn infinite_tau_handle_enclosing_semantics() {
    let data = cloud(26, 3, 55);
    // Enclosing ON at ingest: the handle holds the truncated set; τ=∞
    // queries serve it unchanged and sub-τ queries prefix it.
    let opts_on = EngineOptions {
        max_dim: 1,
        threads: 2,
        enclosing: true,
        ..Default::default()
    };
    let s_on = Session::new(opts_on.clone());
    let h_on = s_on.ingest(&data, f64::INFINITY).unwrap();
    assert!(h_on.stats().enclosing_radius.is_finite());
    let full = s_on.query(&h_on, &PhRequest::at(f64::INFINITY)).unwrap();
    let fresh = compute_ph(&data, f64::INFINITY, &opts_on);
    assert_eq!(diagram_bits(&full.result.diagram), diagram_bits(&fresh.diagram));
    let sub = s_on.query(&h_on, &PhRequest::at(0.4)).unwrap();
    let fresh_sub = compute_ph(&data, 0.4, &opts_on);
    assert_eq!(diagram_bits(&sub.result.diagram), diagram_bits(&fresh_sub.diagram));
    // Finite τ at/beyond r_enc: servable from the truncated set (the
    // complex is a cone past r_enc), consistent with tau_capacity() = ∞.
    // The fresh untruncated run at that τ has extra cone edges whose
    // pairs are all zero-persistence, so diagrams are multiset-equal at
    // zero tolerance.
    let r_enc = h_on.stats().enclosing_radius;
    let beyond = s_on.query(&h_on, &PhRequest::at(r_enc * 1.5)).unwrap();
    // The response must report the clamp: the requested τ exceeds the
    // handle's truncated set, so the served cut is r_enc, not τ.
    assert!(beyond.truncated);
    assert_eq!(beyond.n_edges, h_on.n_edges());
    assert_eq!(beyond.tau_effective.to_bits(), r_enc.to_bits());
    let fresh_beyond = compute_ph(&data, r_enc * 1.5, &opts_on);
    assert!(
        beyond
            .result
            .diagram
            .multiset_eq(&fresh_beyond.diagram, 0.0),
        "cone-range query must be diagram-equal to the fresh run"
    );
    // ... but an explicit enclosing=false override needs edges the
    // ingest pruned: a typed refusal, not silence.
    let err = s_on
        .query(
            &h_on,
            &PhRequest {
                tau: f64::INFINITY,
                enclosing: Some(false),
                ..Default::default()
            },
        )
        .unwrap_err();
    assert!(matches!(err, DoryError::Request(_)), "{err}");

    // Enclosing OFF at ingest (complete handle): a query-time
    // enclosing=true override derives r_enc from the shared edge set
    // and must match a fresh enclosing-on run bit for bit.
    let opts_off = EngineOptions {
        enclosing: false,
        ..opts_on.clone()
    };
    let s_off = Session::new(opts_off);
    let h_off = s_off.ingest(&data, f64::INFINITY).unwrap();
    let n = data.n();
    assert_eq!(h_off.n_edges(), n * (n - 1) / 2, "complete pair list");
    let cut = s_off
        .query(
            &h_off,
            &PhRequest {
                tau: f64::INFINITY,
                enclosing: Some(true),
                ..Default::default()
            },
        )
        .unwrap();
    assert!(cut.truncated, "query-time truncation must fire");
    assert_eq!(diagram_bits(&cut.result.diagram), diagram_bits(&fresh.diagram));
    assert_eq!(cut.tau_effective.to_bits(), h_on.stats().enclosing_radius.to_bits());
}

#[test]
fn sparse_handle_queries_match_fresh_runs() {
    // Sparse (pre-thresholded) inputs: prefix queries over the COO set.
    let mut rng = Pcg32::new(99);
    let n = 40usize;
    let mut entries = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            if rng.next_f64() < 0.4 {
                entries.push((u, v, rng.uniform(0.1, 2.0)));
            }
        }
    }
    let data = MetricData::Sparse(SparseDistances { n, entries });
    let opts = EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    };
    let session = Session::new(opts.clone());
    let handle = session.ingest(&data, f64::INFINITY).unwrap();
    for tau in [0.5, 1.0, 1.7, f64::INFINITY] {
        let resp = session.query(&handle, &PhRequest::at(tau)).unwrap();
        let fresh = compute_ph(&data, tau, &opts);
        assert_eq!(
            diagram_bits(&resp.result.diagram),
            diagram_bits(&fresh.diagram),
            "sparse tau={tau}"
        );
    }
    assert_eq!(session.stats().filtration_builds, 1);
}

#[test]
fn per_request_override_sweep_matches_fresh_runs() {
    // shortcut / max_dim overrides per request, against fresh runs with
    // the same effective options.
    let data = cloud(22, 3, 31);
    let base = EngineOptions {
        max_dim: 2,
        threads: 2,
        shortcut: true,
        ..Default::default()
    };
    let session = Session::new(base.clone());
    let handle = session.ingest(&data, 0.85).unwrap();
    for tau in [0.5, 0.85] {
        for shortcut in [true, false] {
            for max_dim in [1usize, 2] {
                let req = PhRequest {
                    tau,
                    max_dim: Some(max_dim),
                    shortcut: Some(shortcut),
                    ..Default::default()
                };
                let resp = session.query(&handle, &req).unwrap();
                let fresh = compute_ph(
                    &data,
                    tau,
                    &EngineOptions {
                        max_dim,
                        shortcut,
                        ..base.clone()
                    },
                );
                assert_eq!(
                    diagram_bits(&resp.result.diagram),
                    diagram_bits(&fresh.diagram),
                    "tau={tau} shortcut={shortcut} max_dim={max_dim}"
                );
            }
        }
    }
    assert_eq!(session.stats().filtration_builds, 1);
}

// ---------------------------------------------------------------------
// Typed error paths
// ---------------------------------------------------------------------

#[test]
fn nan_ingest_is_invalid_input() {
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 1,
        ..Default::default()
    });
    let nan_points = MetricData::Points(PointCloud::new(2, vec![0.0, 0.0, f64::NAN, 1.0]));
    let e = session.ingest(&nan_points, 1.0).unwrap_err();
    assert!(matches!(e, DoryError::InvalidInput(_)), "{e}");
    assert!(e.to_string().contains("NaN"), "{e}");
    let nan_sparse = MetricData::Sparse(SparseDistances {
        n: 3,
        entries: vec![(0, 1, f64::NAN)],
    });
    assert!(matches!(
        session.ingest(&nan_sparse, 1.0).unwrap_err(),
        DoryError::InvalidInput(_)
    ));
    // The session is still usable after a refused ingest.
    let ok = session.ingest(&cloud(10, 2, 1), 1.0).unwrap();
    assert!(session.query(&ok, &PhRequest::at(0.5)).is_ok());
}

#[test]
fn dory_ns_overflow_guard_is_typed() {
    // A vertex count whose n(n-1)/2 table cannot exist: the session
    // refuses with Overflow before allocating anything.
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 1,
        dense_lookup: true,
        ..Default::default()
    });
    let fake = EdgeFiltration {
        n: u32::MAX - 2,
        edges: Vec::new(),
        values: Vec::new(),
        tau_max: 1.0,
    };
    let e = session
        .ingest_filtration(fake, PhaseTimer::new(), FiltrationStats::default(), "test")
        .unwrap_err();
    assert!(matches!(e, DoryError::Overflow(_)), "{e}");
    assert!(e.to_string().contains("DoryNS"), "{e}");
}

#[test]
fn tau_beyond_ingest_is_typed_and_recoverable() {
    let data = cloud(16, 3, 77);
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 1,
        ..Default::default()
    });
    let handle = session.ingest(&data, 0.5).unwrap();
    match session.query(&handle, &PhRequest::at(0.75)).unwrap_err() {
        DoryError::TauExceedsIngest {
            requested,
            ingested,
        } => {
            assert_eq!(requested, 0.75);
            assert_eq!(ingested, 0.5);
        }
        other => panic!("wrong variant: {other}"),
    }
    // Re-ingesting at the larger τ serves it (the documented recovery).
    let wider = session.ingest(&data, 0.75).unwrap();
    assert!(session.query(&wider, &PhRequest::at(0.75)).is_ok());
    assert_eq!(session.stats().ingests, 2);
}

#[test]
fn bad_toml_is_typed_config_error() {
    for bad in [
        "[engine]\nbogus = 1\n",
        "[bogus]\n",
        "[engine]\nmax_dim = 7\n",
        "[engine]\ntau = \"high\"\n",
        "[[query]]\nmax_dim = 1\n",
        "[[query]]\ntau = 0.5\nunknown_knob = true\n",
        "[engine\ntau = 1\n",
    ] {
        let e = RunConfig::from_str(bad).unwrap_err();
        assert!(matches!(e, DoryError::Config(_)), "{bad:?} gave {e}");
    }
    // Missing config files are Io, not Config.
    assert!(matches!(
        RunConfig::from_file(std::path::Path::new("/definitely/not/here.toml")).unwrap_err(),
        DoryError::Io(_)
    ));
}

// ---------------------------------------------------------------------
// Coordinator batch mode
// ---------------------------------------------------------------------

#[test]
fn coordinator_query_array_matches_single_runs() {
    let dir = std::env::temp_dir().join("dory-session-test-batch");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = RunConfig {
        dataset: DatasetSpec::Named {
            kind: "figure-eight".into(),
            n: 60,
            seed: 9,
        },
        tau: 1.5,
        max_dim: 1,
        threads: 2,
        use_pjrt: false,
        summary_json: Some(dir.join("summary.json")),
        diagram_csv: Some(dir.join("pd.csv")),
        queries: vec![
            QuerySpec {
                label: Some("coarse".into()),
                ..QuerySpec::at(0.6)
            },
            QuerySpec::at(1.0),
            QuerySpec::at(1.5),
        ],
        ..Default::default()
    };
    let batch = coordinator::run_batch(&cfg).unwrap();
    assert_eq!(batch.responses.len(), 3);
    assert_eq!(batch.session.filtration_builds, 1);
    assert_eq!(batch.session.nb_builds, 1);
    for (i, q) in cfg.queries.iter().enumerate() {
        let single = coordinator::run(&RunConfig {
            tau: q.tau,
            queries: Vec::new(),
            summary_json: None,
            diagram_csv: None,
            ..cfg.clone()
        })
        .unwrap();
        assert_eq!(
            diagram_bits(&batch.responses[i].result.diagram),
            diagram_bits(&single.result.diagram),
            "query {i} (tau={})",
            q.tau
        );
        assert!(dir.join(format!("pd.q{i}.csv")).is_file(), "pd.q{i}.csv");
    }
    let summary = std::fs::read_to_string(dir.join("summary.json")).unwrap();
    assert!(summary.contains("\"queries\""), "{summary}");
    assert!(summary.contains("\"label\":\"coarse\""), "{summary}");
    assert!(summary.contains("\"session\""), "{summary}");
    assert!(summary.contains("\"filtration_builds\":1"), "{summary}");
}

#[test]
fn coordinator_surfaces_out_of_capacity_query() {
    // A [[query]] τ above every other τ defines the ingest threshold,
    // so batches are self-consistent; but a handle ingested at a finite
    // τ refuses an ∞ query with the typed error end to end.
    let cfg = RunConfig {
        dataset: DatasetSpec::Named {
            kind: "circle".into(),
            n: 40,
            seed: 2,
        },
        tau: 1.0,
        max_dim: 1,
        threads: 1,
        use_pjrt: false,
        queries: vec![QuerySpec::at(0.5), QuerySpec::at(f64::INFINITY)],
        ..Default::default()
    };
    // ingest_tau covers the ∞ query, so this succeeds (enclosing fires).
    assert_eq!(cfg.ingest_tau(), f64::INFINITY);
    let b = coordinator::run_batch(&cfg).unwrap();
    assert_eq!(b.responses.len(), 2);

    // Bad dataset kinds keep their typed error through run_batch.
    let e = coordinator::run_batch(&RunConfig {
        dataset: DatasetSpec::Named {
            kind: "no-such".into(),
            n: 10,
            seed: 1,
        },
        ..cfg
    })
    .unwrap_err();
    assert!(matches!(e, DoryError::Dataset(_)), "{e}");
}

#[test]
fn legacy_shims_still_pin_one_shot_behavior() {
    // compute_ph (the deprecated shim) must agree with an explicitly
    // session-served query — the migration is a pure refactor.
    let data = cloud(20, 3, 123);
    let opts = EngineOptions {
        max_dim: 2,
        threads: 2,
        ..Default::default()
    };
    let one_shot = compute_ph(&data, 0.8, &opts);
    let session = Session::new(opts);
    let handle = session.ingest(&data, 0.8).unwrap();
    let served = session.query(&handle, &PhRequest::at(0.8)).unwrap();
    assert_eq!(
        diagram_bits(&one_shot.diagram),
        diagram_bits(&served.result.diagram)
    );
    assert_eq!(one_shot.stats.n_edges, served.n_edges);
}
