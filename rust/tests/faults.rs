//! Fault-injection acceptance suite: the server and the ingest
//! pipeline must *survive* injected spill I/O failures, worker panics,
//! deadlines, and overload — answering typed errors on the wire and
//! serving bit-identical diagrams once the fault clears.
//!
//! Failpoint state is process-global, so every test that arms one
//! takes [`failpoint::test_lock`] through [`FaultScope`] (which also
//! clears the registry on entry and exit); tests that inject nothing
//! run lock-free.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;

use dory::error::DoryError;
use dory::homology::{EngineOptions, PhRequest, Session};
use dory::io::stream::StreamOptions;
use dory::serve::Server;
use dory::util::failpoint::{self, Trigger};
use dory::util::json::Json;

/// Serialize failpoint-arming tests and guarantee a clean registry on
/// both entry and exit, even when an assertion panics mid-test.
struct FaultScope(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl FaultScope {
    fn new() -> Self {
        let guard = failpoint::test_lock();
        failpoint::clear();
        Self(guard)
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        failpoint::clear();
    }
}

/// A fresh per-test spill directory.
fn fault_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dory-faults-it-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A complete graph on `n` vertices with deterministic distances —
/// small enough to be fast, dense enough to spill under a 2 KiB budget.
fn write_coo(name: &str, n: u32) -> (PathBuf, PathBuf) {
    let dir = fault_dir(name);
    let p = dir.join("edges.coo");
    let mut text = String::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let d = 1.0 + ((i * 31 + j * 7) % 13) as f64 / 10.0;
            text.push_str(&format!("{i} {j} {d}\n"));
        }
    }
    std::fs::write(&p, text).unwrap();
    (p, dir)
}

fn spill_opts(dir: &PathBuf, strict: bool) -> StreamOptions {
    StreamOptions {
        chunk_lines: 16,
        budget_bytes: 2048,
        spill_dir: Some(dir.clone()),
        strict,
    }
}

fn session() -> Session {
    Session::new(EngineOptions {
        threads: 2,
        ..Default::default()
    })
}

fn diagram_bits(d: &dory::homology::Diagram) -> Vec<(usize, u64, u64)> {
    let mut out = Vec::new();
    for dim in 0..=d.max_dim() {
        for p in d.points(dim) {
            out.push((dim, p.birth.to_bits(), p.death.to_bits()));
        }
    }
    out
}

fn query_bits(s: &Session, h: &dory::homology::FiltrationHandle, tau: f64) -> Vec<(usize, u64, u64)> {
    let req = PhRequest {
        tau,
        max_dim: Some(1),
        ..Default::default()
    };
    diagram_bits(&s.query(h, &req).unwrap().result.diagram)
}

#[test]
fn spill_write_fault_mid_ingest_strict_is_typed_and_leaves_dir_clean() {
    let _scope = FaultScope::new();
    let (p, dir) = write_coo("strict-write", 48);
    failpoint::arm(failpoint::SPILL_WRITE, Trigger::Always);
    let s = session();
    let e = s
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, true))
        .unwrap_err();
    assert!(matches!(e, DoryError::Io(_)), "{e}");
    assert!(e.to_string().contains("failpoint"), "{e}");
    // The failed ingest removed every partial spill run.
    let stray: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("dory-spill-"))
        .collect();
    assert!(stray.is_empty(), "stray spill files: {stray:?}");
}

#[test]
fn spill_write_retry_then_succeed_is_bit_identical_end_to_end() {
    // Take the lock before the baseline too: a sibling test's armed
    // failpoint must not degrade (or fail) the fault-free reference run.
    let _scope = FaultScope::new();
    let (p, dir) = write_coo("retry-write", 48);
    let base = session();
    let (h0, st0) = base
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap();
    assert!(st0.spilled_runs > 0, "fixture must actually spill");
    let want = query_bits(&base, &h0, 2.0);

    failpoint::arm(failpoint::SPILL_WRITE, Trigger::Nth(1));
    let s = session();
    let (h, st) = s
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap();
    assert!(st.io_retries >= 1, "the injected fault must be retried");
    assert!(!st.degraded);
    assert_eq!(st.spilled_runs, st0.spilled_runs);
    assert_eq!(query_bits(&s, &h, 2.0), want);
}

#[test]
fn degraded_ingest_is_flagged_and_bit_identical() {
    let _scope = FaultScope::new();
    let (p, dir) = write_coo("degrade", 48);
    let base = session();
    let (h0, _) = base
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap();
    let want = query_bits(&base, &h0, 2.0);

    failpoint::arm(failpoint::SPILL_WRITE, Trigger::Always);
    let s = session();
    let (h, st) = s
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap();
    assert!(st.degraded, "an unwritable spill dir must degrade");
    assert_eq!(st.spilled_runs, 0);
    assert_eq!(query_bits(&s, &h, 2.0), want);
    drop(_scope);
    let stray: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("dory-spill-"))
        .collect();
    assert!(stray.is_empty(), "stray spill files: {stray:?}");
}

#[test]
fn merge_open_fault_is_typed_and_leaves_dir_clean() {
    let _scope = FaultScope::new();
    let (p, dir) = write_coo("merge-open", 48);
    failpoint::arm(failpoint::MERGE_OPEN, Trigger::Always);
    let s = session();
    let e = s
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap_err();
    assert!(matches!(e, DoryError::Io(_)), "{e}");
    drop(_scope);
    let stray: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("dory-spill-"))
        .collect();
    assert!(stray.is_empty(), "stray spill files: {stray:?}");
}

#[test]
fn deadline_exceeded_leaves_handle_fully_serviceable() {
    // Arms nothing, but the spilling ingest below must not trip a
    // sibling test's armed spill/merge failpoint.
    let _scope = FaultScope::new();
    let (p, dir) = write_coo("deadline", 48);
    let s = session();
    let (h, _) = s
        .ingest_sparse_file(&p, f64::INFINITY, &spill_opts(&dir, false))
        .unwrap();
    let want = query_bits(&s, &h, 2.0);
    // An already-expired deadline aborts typed, mid-validation.
    let e = s
        .query(
            &h,
            &PhRequest {
                tau: 2.0,
                max_dim: Some(1),
                timeout_ms: Some(0),
                ..Default::default()
            },
        )
        .unwrap_err();
    assert!(matches!(e, DoryError::DeadlineExceeded(_)), "{e}");
    // The aborted query left nothing behind: same handle, same bits.
    assert_eq!(query_bits(&s, &h, 2.0), want);
}

/// Drive one request line against a serve front and parse the response.
fn wire(srv: &Server, line: &str) -> Json {
    let (resp, _stop) = srv.handle_line(line);
    resp
}

fn wire_ingest_circle(srv: &Server, n: usize) -> String {
    let resp = wire(
        srv,
        &format!(
            "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"kind\":\"circle\",\"n\":{n},\"seed\":7}}}}"
        ),
    );
    resp.get("ok")
        .unwrap_or_else(|| panic!("ingest failed: {}", resp.render()))
        .get("handle")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

#[test]
fn injected_serve_panic_is_internal_then_service_is_bit_identical() {
    let _scope = FaultScope::new();
    let srv = Server::new(
        EngineOptions {
            threads: 2,
            ..Default::default()
        },
        64 << 20,
    );
    let key = wire_ingest_circle(&srv, 40);
    let q = format!("{{\"id\":2,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}");
    let want = wire(&srv, &q).get("ok").unwrap().get("betti").unwrap().render();
    failpoint::arm(failpoint::SERVE_QUERY_PANIC, Trigger::Nth(1));
    let resp = wire(&srv, &q);
    let e = resp.get("error").unwrap();
    assert_eq!(e.get("kind").unwrap().as_str(), Some("Internal"));
    failpoint::clear();
    // The caught panic changed nothing the next request can observe.
    let got = wire(&srv, &q).get("ok").unwrap().get("betti").unwrap().render();
    assert_eq!(got, want);
    let summary = wire(&srv, "{\"id\":3,\"method\":\"stats\"}");
    let rc = summary.get("ok").unwrap().get("resilience").unwrap();
    assert_eq!(rc.get("panics").unwrap().as_usize(), Some(1));
}

#[test]
fn overload_flood_sheds_typed_while_the_other_tenant_completes() {
    // Arms nothing, but a sibling's serve-query-panic failpoint would
    // turn flood queries into Internal errors and break the typed-shed
    // assertion — hold the lock for the test's duration.
    let _scope = FaultScope::new();
    let srv = Server::new(
        EngineOptions {
            threads: 2,
            ..Default::default()
        },
        64 << 20,
    )
    .with_overload(2, 1);
    let key = wire_ingest_circle(&srv, 48);

    const FLOODERS: usize = 8;
    const PER_THREAD: usize = 20;
    let shed_seen = AtomicU64::new(0);
    let ok_seen = AtomicU64::new(0);
    let barrier = Barrier::new(FLOODERS);
    std::thread::scope(|scope| {
        for t in 0..FLOODERS {
            let (srv, key, barrier, shed_seen, ok_seen) =
                (&srv, &key, &barrier, &shed_seen, &ok_seen);
            scope.spawn(move || {
                barrier.wait();
                for i in 0..PER_THREAD {
                    let line = format!(
                        "{{\"id\":{},\"tenant\":\"flood\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}",
                        t * PER_THREAD + i
                    );
                    let resp = wire(srv, &line);
                    if resp.get("ok").is_some() {
                        ok_seen.fetch_add(1, Ordering::Relaxed);
                    } else {
                        let e = resp.get("error").unwrap();
                        // Every refusal is the typed overload error —
                        // never a panic, lock poison, or misparse.
                        assert_eq!(
                            e.get("kind").unwrap().as_str(),
                            Some("Overloaded"),
                            "{}",
                            resp.render()
                        );
                        shed_seen.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    // Concurrency did happen: the quota of 1 shed overlapping load, and
    // plenty of the flood still got through.
    assert!(shed_seen.load(Ordering::Relaxed) > 0, "flood never overlapped");
    assert!(ok_seen.load(Ordering::Relaxed) > 0, "everything was shed");
    // The calm tenant is admitted (quota is per-tenant; capacity 2
    // leaves headroom now the flood is over) and served correctly.
    let calm = wire(
        &srv,
        &format!("{{\"id\":99,\"tenant\":\"calm\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}"),
    );
    assert!(calm.get("ok").is_some(), "{}", calm.render());
    let summary = wire(&srv, "{\"id\":100,\"method\":\"stats\"}");
    let rc = summary.get("ok").unwrap().get("resilience").unwrap();
    assert_eq!(
        rc.get("shed").unwrap().as_usize().unwrap() as u64,
        shed_seen.load(Ordering::Relaxed)
    );
}

#[test]
fn wire_ingest_with_spill_fault_degrades_flagged_and_counted() {
    let _scope = FaultScope::new();
    // 420 vertices → ~88k edges ≈ 1.4 MiB of staged keys, which is
    // past the 1 MiB wire budget: the ingest *must* try to spill, so
    // the armed failpoint must fire and the ingest must degrade.
    let (p, _dir) = write_coo("wire-degrade", 420);
    let srv = Server::new(
        EngineOptions {
            threads: 2,
            ..Default::default()
        },
        64 << 20,
    );
    failpoint::arm(failpoint::SPILL_WRITE, Trigger::Always);
    let pd = p.display();
    let resp = wire(
        &srv,
        &format!(
            "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{pd}\",\"edge_budget_mb\":1,\"stream_chunk\":4096}}}}"
        ),
    );
    failpoint::clear();
    let ok = resp
        .get("ok")
        .unwrap_or_else(|| panic!("degraded ingest must succeed: {}", resp.render()));
    assert_eq!(ok.get("degraded").unwrap().as_bool(), Some(true));
    assert_eq!(ok.get("n_points").unwrap().as_usize(), Some(420));
    let summary = wire(&srv, "{\"id\":2,\"method\":\"stats\"}");
    let rc = summary.get("ok").unwrap().get("resilience").unwrap();
    assert_eq!(rc.get("degraded_ingests").unwrap().as_usize(), Some(1));
    assert!(rc.get("ingest_io_retries").unwrap().as_usize().unwrap() >= 1);
}
