//! Property-based tests (hand-rolled harness, seeded PCG — no proptest in
//! the offline vendor set). Each property runs across a sweep of random
//! instances; failures print the seed for exact reproduction.

use dory::baselines::ripser_like;
use dory::filtration::{EdgeFiltration, Neighborhoods};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph_from_filtration, Algorithm, EngineOptions};
use dory::reduction::explicit::oracle_diagram;
use dory::util::rng::Pcg32;

fn random_cloud(rng: &mut Pcg32, max_n: usize, dim: usize) -> MetricData {
    let n = 8 + rng.gen_range((max_n - 8) as u32) as usize;
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

/// Random weighted graph — NOT a metric. VR filtrations are defined for
/// arbitrary symmetric weights (the Hi-C inputs are not metric either).
fn random_graph(rng: &mut Pcg32, max_n: u32) -> MetricData {
    let n = 6 + rng.gen_range(max_n - 6);
    let mut entries = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < 0.55 {
                entries.push((i, j, rng.uniform(0.05, 1.0)));
            }
        }
    }
    MetricData::Sparse(SparseDistances {
        n: n as usize,
        entries,
    })
}

#[test]
fn property_dory_matches_oracle_on_clouds() {
    // 60 random clouds x dims {2,3} x homology dim 2, vs the textbook
    // boundary-matrix reduction.
    for seed in 0..30u64 {
        let mut rng = Pcg32::new(0xC10D + seed);
        for dim in [2usize, 3] {
            let data = random_cloud(&mut rng, 22, dim);
            let tau = rng.uniform(0.4, 0.9);
            let f = EdgeFiltration::build(&data, tau);
            let nb = Neighborhoods::build(&f, false);
            let got = compute_ph_from_filtration(
                &f,
                &EngineOptions {
                    max_dim: 2,
                    ..Default::default()
                },
            )
            .diagram;
            let want = oracle_diagram(&f, &nb, 2);
            assert!(
                got.multiset_eq(&want, 1e-9),
                "seed={seed} dim={dim} tau={tau}\n{}",
                got.diff_summary(&want)
            );
        }
    }
}

#[test]
fn property_dory_matches_oracle_on_nonmetric_graphs() {
    for seed in 0..30u64 {
        let mut rng = Pcg32::new(0x6AF + seed);
        let data = random_graph(&mut rng, 18);
        let f = EdgeFiltration::build(&data, f64::INFINITY);
        let nb = Neighborhoods::build(&f, false);
        let got = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        )
        .diagram;
        let want = oracle_diagram(&f, &nb, 2);
        assert!(
            got.multiset_eq(&want, 1e-9),
            "seed={seed}\n{}",
            got.diff_summary(&want)
        );
    }
}

#[test]
fn property_engine_configs_are_equivalent() {
    // fast-column/implicit-row x sparse/dense-lookup x batch sizes x
    // threads must give identical diagrams on random instances.
    for seed in 0..12u64 {
        let mut rng = Pcg32::new(0xBEEF + seed);
        let data = random_cloud(&mut rng, 26, 3);
        let tau = rng.uniform(0.5, 1.0);
        let f = EdgeFiltration::build(&data, tau);
        let reference = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        )
        .diagram;
        for algorithm in [Algorithm::FastColumn, Algorithm::ImplicitRow] {
            for (threads, batch) in [(1usize, 100usize), (3, 2), (4, 17)] {
                for dense in [false, true] {
                    let d = compute_ph_from_filtration(
                        &f,
                        &EngineOptions {
                            max_dim: 2,
                            threads,
                            batch_size: batch,
                            adaptive_batch: false,
                            dense_lookup: dense,
                            algorithm,
                            ..Default::default()
                        },
                    )
                    .diagram;
                    assert!(
                        d.multiset_eq(&reference, 1e-12),
                        "seed={seed} algo={algorithm:?} threads={threads} batch={batch} dense={dense}"
                    );
                }
            }
        }
    }
}

#[test]
fn property_monotone_tau_nests_diagrams() {
    // Persistence pairs with death <= tau_small must appear identically
    // when computed at a larger tau (filtration restriction property).
    for seed in 0..10u64 {
        let mut rng = Pcg32::new(0x7A0 + seed);
        let data = random_cloud(&mut rng, 30, 2);
        let (t1, t2) = (0.45, 0.85);
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let small = compute_ph_from_filtration(&EdgeFiltration::build(&data, t1), &opts).diagram;
        let large = compute_ph_from_filtration(&EdgeFiltration::build(&data, t2), &opts).diagram;
        for dim in 0..=1 {
            let mut sm: Vec<(f64, f64)> = small
                .finite(dim)
                .iter()
                .map(|p| (p.birth, p.death))
                .collect();
            let mut lg: Vec<(f64, f64)> = large
                .finite(dim)
                .iter()
                .filter(|p| p.death <= t1)
                .map(|p| (p.birth, p.death))
                .collect();
            sm.retain(|p| p.1 <= t1);
            sm.sort_by(|a, b| a.partial_cmp(b).unwrap());
            lg.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(sm.len(), lg.len(), "seed={seed} dim={dim}");
            for (a, b) in sm.iter().zip(&lg) {
                assert!((a.0 - b.0).abs() < 1e-12 && (a.1 - b.1).abs() < 1e-12);
            }
        }
    }
}

#[test]
fn property_betti_counts_match_euler_characteristic() {
    // For the full complex at tau=inf on n points, chi = sum (-1)^k C(n,k+1)
    // telescopes to 1; PH at dim<=2 can't see all of that, but beta0 must
    // be 1 and all essential classes above dim 0 must vanish (a simplex is
    // contractible).
    for seed in 0..8u64 {
        let mut rng = Pcg32::new(0xE1 + seed);
        let data = random_cloud(&mut rng, 16, 3);
        let f = EdgeFiltration::build(&data, f64::INFINITY);
        let r = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        );
        assert_eq!(r.diagram.essential_count(0), 1, "seed={seed}");
        assert_eq!(r.diagram.essential_count(1), 0, "seed={seed}");
        assert_eq!(r.diagram.essential_count(2), 0, "seed={seed}");
    }
}

#[test]
fn property_ripser_like_matches_on_graphs() {
    // Baseline independence check on sparse non-metric inputs too.
    for seed in 0..12u64 {
        let mut rng = Pcg32::new(0x517 + seed);
        let data = random_graph(&mut rng, 16);
        let f = EdgeFiltration::build(&data, f64::INFINITY);
        let dory = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        )
        .diagram;
        let rip = ripser_like::compute_ph(&data, 1e9, 2, usize::MAX).unwrap();
        assert!(
            dory.multiset_eq(&rip, 2e-4),
            "seed={seed}\n{}",
            dory.diff_summary(&rip)
        );
    }
}
