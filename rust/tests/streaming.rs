//! Streaming-ingest differential harness.
//!
//! Pins the tentpole contract of the streamed sparse front-end:
//!
//! * **chunking invariance** — the streamed filtration is byte-identical
//!   to the in-memory reader's for every chunk size, because edge keys
//!   are strictly unique and the k-way merge respects their total order;
//! * **budget invariance** — spilling (any number of runs) never changes
//!   a byte of the output, only where the runs briefly lived;
//! * **the acceptance case** — a ≥1M-edge file ingests under a 4 MiB
//!   staging budget with resident staging tracking the budget rather
//!   than the input size (asserted via the counting allocator), and the
//!   diagram bit-equal to the in-memory path's at tolerance zero.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::PathBuf;

use dory::filtration::{EdgeFiltration, FiltrationStats};
use dory::homology::{EngineOptions, PhRequest, Session};
use dory::io;
use dory::io::stream::{stream_sparse_file, StreamOptions};
use dory::util::memtrack;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("dory-streaming-it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn diagram_bits(d: &dory::homology::Diagram) -> Vec<(usize, u64, u64)> {
    let mut out = Vec::new();
    for dim in 0..=d.max_dim() {
        for p in d.points(dim) {
            out.push((dim, p.birth.to_bits(), p.death.to_bits()));
        }
    }
    out
}

fn req(tau: f64, max_dim: usize) -> PhRequest {
    PhRequest {
        tau,
        max_dim: Some(max_dim),
        ..Default::default()
    }
}

/// A small dense-ish sparse file: every pair of 60 vertices, distances
/// deterministic, odd lines orientation-flipped, comments and blank
/// lines sprinkled in. τ = 1.2 leaves some entries above threshold so
/// the reader-side filter is exercised.
fn write_small(name: &str) -> PathBuf {
    let p = tmp(name);
    let mut text = String::from("# streaming differential fixture\n\n");
    let mut line = 0u32;
    for i in 0..60u32 {
        for j in (i + 1)..60 {
            let d = 0.1 + ((i * 61 + j * 17) % 173) as f64 / 100.0;
            if line % 2 == 0 {
                text.push_str(&format!("{i} {j} {d}\n"));
            } else {
                text.push_str(&format!("{j} {i} {d}\n"));
            }
            line += 1;
        }
    }
    std::fs::write(&p, text).unwrap();
    p
}

#[test]
fn streamed_filtration_matches_in_memory_across_chunks_and_budgets() {
    let p = write_small("diff.coo");
    let tau = 1.2;
    let md = io::read_sparse_coo(&p).unwrap();
    let oracle = EdgeFiltration::build(&md, tau);
    assert!(oracle.n_edges() > 0);
    let oracle_bits: Vec<u64> = oracle.values.iter().map(|v| v.to_bits()).collect();

    for chunk in [1usize, 7, 4096] {
        for budget in [0usize, 1 << 12] {
            let opts = StreamOptions {
                chunk_lines: chunk,
                budget_bytes: budget,
                spill_dir: None,
                strict: false,
            };
            let mut fs = FiltrationStats::default();
            let (f, st) = stream_sparse_file(&p, tau, &opts, None, &mut fs).unwrap();
            assert_eq!(f.n, oracle.n, "chunk {chunk} budget {budget}");
            assert_eq!(f.edges, oracle.edges, "chunk {chunk} budget {budget}");
            let bits: Vec<u64> = f.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits, oracle_bits, "chunk {chunk} budget {budget}");
            assert_eq!(f.tau_max.to_bits(), tau.to_bits());
            // Counter sanity: every data line is one validated entry,
            // and the kept count is exactly the output size.
            assert_eq!(st.lines, 60 * 59 / 2);
            assert_eq!(st.entries, st.lines);
            assert_eq!(st.kept as usize, f.n_edges());
            assert!(fs.f1_builds == 1 && fs.edges_kept == st.kept);
            if budget > 0 {
                // ~14 KiB of entries against a 4 KiB budget must spill,
                // and resident staging must track the budget + chunk
                // scratch, not the input.
                assert!(st.spilled_runs > 0, "budget {budget} did not spill");
                let chunk_bytes = chunk * std::mem::size_of::<(u32, u32, f64)>();
                assert!(
                    st.staging_peak_bytes <= budget + chunk_bytes + 4096,
                    "staging {} exceeds budget {budget} + chunk {chunk_bytes}",
                    st.staging_peak_bytes
                );
            }
        }
    }
}

#[test]
fn streamed_session_diagrams_are_bit_identical() {
    let p = write_small("diff-pd.coo");
    let tau = 1.2;
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let md = io::read_sparse_coo(&p).unwrap();
    let h_mem = session.ingest(&md, tau).unwrap();
    let want = diagram_bits(&session.query(&h_mem, &req(tau, 1)).unwrap().result.diagram);
    for budget in [0usize, 1 << 12] {
        let opts = StreamOptions {
            chunk_lines: 7,
            budget_bytes: budget,
            spill_dir: None,
            strict: false,
        };
        let (h, _st) = session.ingest_sparse_file(&p, tau, &opts).unwrap();
        assert_eq!(h.edge_source, "stream");
        assert_eq!(h.n_edges(), h_mem.n_edges());
        let got = diagram_bits(&session.query(&h, &req(tau, 1)).unwrap().result.diagram);
        assert_eq!(got, want, "budget {budget}");
    }
}

/// Dense streaming through the session: a budgeted `ingest_streamed`
/// spills pool-sorted runs, keeps resident staging in
/// O(budget + wave scratch), and produces diagrams bit-identical to the
/// unbudgeted in-memory ingest — including the enclosing-radius
/// truncation, which runs as a standalone row-max sweep on this path.
#[test]
fn dense_streamed_session_spills_and_matches_in_memory() {
    let n = 140usize;
    let data = dory::datasets::sphere(n, 1.0, 0.05, 0xDE5E);
    let threads = 2usize;
    let tile = 4usize;
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads,
        f1_tile: tile,
        ..Default::default()
    });
    let h_mem = session.ingest(&data, f64::INFINITY).unwrap();
    assert_eq!(h_mem.edge_source, "native");
    let want = diagram_bits(
        &session
            .query(&h_mem, &req(f64::INFINITY, 1))
            .unwrap()
            .result
            .diagram,
    );
    let r_enc_mem = h_mem.stats().enclosing_radius;
    assert!(r_enc_mem.is_finite(), "sphere must truncate at r_enc");

    for budget in [0usize, 4096] {
        let opts = StreamOptions {
            chunk_lines: 0,
            budget_bytes: budget,
            spill_dir: None,
            strict: false,
        };
        let (h, st) = session
            .ingest_streamed(&data, f64::INFINITY, &opts)
            .unwrap();
        assert_eq!(h.edge_source, "dense-stream");
        assert_eq!(h.n_edges(), h_mem.n_edges(), "budget {budget}");
        let fs = h.stats();
        assert_eq!(
            fs.enclosing_radius.to_bits(),
            r_enc_mem.to_bits(),
            "budget {budget}: r_enc"
        );
        assert!(
            ["scalar", "avx2", "neon"].contains(&fs.dist_kernel),
            "budget {budget}: kernel {:?}",
            fs.dist_kernel
        );
        let got = diagram_bits(&session.query(&h, &req(f64::INFINITY, 1)).unwrap().result.diagram);
        assert_eq!(got, want, "budget {budget}: diagram deviates");
        if budget == 0 {
            assert_eq!(st.spilled_runs, 0, "unbounded budget must not spill");
        } else {
            // ~10k kept keys × 16 B against a 4 KiB budget must spill,
            // and staging must track budget + per-wave scratch (threads
            // row-scratch vectors + one wave of tile key buffers, with
            // 2x capacity slack), not the kept edge set.
            assert!(st.spilled_runs > 0, "4 KiB budget did not spill");
            assert!(st.spilled_bytes > 0);
            assert_eq!(fs.dense_spilled_runs, st.spilled_runs);
            assert_eq!(fs.dense_spilled_bytes, st.spilled_bytes);
            let wave_scratch =
                threads * n * 8 + 2 * threads * tile * n * std::mem::size_of::<u128>();
            assert!(
                st.staging_peak_bytes <= budget + wave_scratch + 4096,
                "staging {} exceeds budget {budget} + wave scratch {wave_scratch}",
                st.staging_peak_bytes
            );
            let full_keys = h_mem.n_edges() * std::mem::size_of::<u128>();
            assert!(
                st.staging_peak_bytes < full_keys,
                "staging {} not below full key set {full_keys}",
                st.staging_peak_bytes
            );
        }
    }
}

#[test]
fn out_of_core_duplicate_detection_survives_spilling() {
    // The duplicate pair sits ~200 lines (many tiny runs) away from its
    // first occurrence, in flipped orientation: only the merged pair
    // stream makes them adjacent.
    let p = tmp("dup-spill.coo");
    let mut text = String::from("3 7 0.5\n");
    for i in 0..200u32 {
        text.push_str(&format!("{} {} 1.0\n", 100 + i, 500 + i));
    }
    text.push_str("7 3 0.9\n");
    std::fs::write(&p, text).unwrap();
    let opts = StreamOptions {
        chunk_lines: 16,
        budget_bytes: 1024,
        spill_dir: None,
        strict: false,
    };
    let mut fs = FiltrationStats::default();
    let e = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("duplicate entry (3, 7)"), "{msg}");
}

#[test]
fn million_edge_ingest_stays_inside_the_budget() {
    // ≥1M edges over ~100k vertices: each vertex joins its next 10
    // neighbors on a line, distances deterministic in [1, 2).
    let p = tmp("million.coo");
    let n = 100_006u32;
    let mut w = BufWriter::new(File::create(&p).unwrap());
    let mut written = 0u64;
    for i in 0..n {
        for k in 1..=10u32 {
            let j = i + k;
            if j >= n {
                break;
            }
            let d = 1.0 + ((i as u64 * 31 + k as u64 * 7) % 997) as f64 / 997.0;
            writeln!(w, "{i} {j} {d}").unwrap();
            written += 1;
        }
    }
    w.flush().unwrap();
    drop(w);
    assert!(written >= 1_000_000, "fixture too small: {written}");

    let tau = 3.0;
    let session = Session::new(EngineOptions {
        max_dim: 0,
        threads: 2,
        ..Default::default()
    });

    // In-memory baseline: full entry vector + full key vector resident.
    memtrack::reset_peak();
    let md = io::read_sparse_coo(&p).unwrap();
    let h_mem = session.ingest(&md, tau).unwrap();
    let peak_mem = memtrack::section_peak_bytes();
    let want = diagram_bits(&session.query(&h_mem, &req(tau, 0)).unwrap().result.diagram);
    let n_edges = h_mem.n_edges();
    assert_eq!(n_edges as u64, written);
    drop(h_mem);
    drop(md);

    // Streamed under a 4 MiB staging budget (default 65536-line chunks).
    let budget = 4usize << 20;
    memtrack::reset_peak();
    let (h_s, st) = session
        .ingest_sparse_file(
            &p,
            tau,
            &StreamOptions {
                chunk_lines: 0,
                budget_bytes: budget,
                spill_dir: None,
                strict: false,
            },
        )
        .unwrap();
    let peak_stream = memtrack::section_peak_bytes();

    assert_eq!(h_s.edge_source, "stream");
    assert_eq!(h_s.n_edges(), n_edges);
    assert!(st.spilled_runs > 0, "a 16 MB key stream must spill at 4 MiB");
    assert!(st.spilled_bytes > 0);
    // Staging = run buffers (≤ budget, pre-sized) + one line chunk.
    let chunk_bytes = 65_536 * std::mem::size_of::<(u32, u32, f64)>();
    assert!(
        st.staging_peak_bytes <= budget + chunk_bytes + (1 << 20),
        "staging {} does not track the {budget}-byte budget",
        st.staging_peak_bytes
    );
    // The whole point: streamed ingest peaks below the in-memory path,
    // which holds the full entry and key vectors simultaneously.
    assert!(
        peak_stream < peak_mem,
        "streamed peak {peak_stream} not below in-memory peak {peak_mem}"
    );

    let got = diagram_bits(&session.query(&h_s, &req(tau, 0)).unwrap().result.diagram);
    assert_eq!(got, want, "streamed diagram deviates from in-memory");
    drop(h_s);
    let _ = std::fs::remove_file(&p);
}
