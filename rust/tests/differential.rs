//! Differential verification of the pipelined work-stealing scheduler
//! and the sharded column enumeration.
//!
//! The paper's guarantee is *exact* equality with the sequential
//! reduction — not closeness. These tests pin that down at three levels:
//!
//! * **engine vs oracle** — the full engine (work-stealing scheduler and
//!   sharded enumeration included) against the explicit boundary-matrix
//!   reduction (`reduction::explicit`), on randomized point clouds
//!   (seeded PCG, n ≤ 200, point dimension ≤ 3) and random sparse
//!   graphs, swept across enumeration shard counts {auto, 1, 5} ×
//!   batch sizes {1, 7, 100} × thread counts {1, 2, 8}, with a zero
//!   tolerance: every birth/death must match to the bit;
//! * **scheduler vs sequential reduction** — `serial_parallel::
//!   reduce_all` against `fast_column::reduce_all` on the same column
//!   set, comparing the *structural* output (pairs, essential columns,
//!   trivial-pair counts) exactly, across pools, batch sizes, steal
//!   grains and adaptive batching;
//! * **enumeration stream** — the sharded H2* column sequence against a
//!   `brute_force_coboundary`-backed sequential enumeration, byte for
//!   byte, over 40 random filtration seeds and several shard plans
//!   (both filled inline and through the work-stealing pool).
//!
//! Failures print the seed for exact reproduction.

use dory::coboundary::edges::{brute_force_coboundary, is_apparent_edge_pair};
use dory::coboundary::triangles::{
    apparent_cofacet, max_equal_facet_of_tet, triangles_with_diameter_in_range,
};
use dory::coboundary::TetCursor;
use dory::filtration::{EdgeFiltration, Key, Neighborhoods};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph_from_filtration, Engine, EngineOptions};
use dory::reduction::explicit::oracle_diagram;
use dory::reduction::pool::ThreadPool;
use dory::reduction::{fast_column, serial_parallel, shard_plan, EdgeColumns, SchedConfig};
use dory::util::rng::Pcg32;

const BATCHES: [usize; 3] = [1, 7, 100];
const THREADS: [usize; 3] = [1, 2, 8];
const ENUM_SHARDS: [usize; 3] = [0, 1, 5];

fn random_cloud(rng: &mut Pcg32, n: usize, dim: usize) -> MetricData {
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

/// Sweep the scheduler grid on one filtration, asserting bit-exact
/// agreement with the explicit oracle diagram. The apparent-pair
/// shortcut is swept on/off across the whole grid: on is the production
/// path (columns resolved in-shard), off is the exact fallback (the
/// reduction's own first-low trivial test), and both must hit the
/// oracle bits.
fn check_instance(f: &EdgeFiltration, max_dim: usize, label: &str) {
    let nb = Neighborhoods::build(f, false);
    let want = oracle_diagram(f, &nb, max_dim);
    for threads in THREADS {
        for shortcut in [true, false] {
            for enum_shards in ENUM_SHARDS {
                for batch in BATCHES {
                    let opts = EngineOptions {
                        max_dim,
                        threads,
                        batch_size: batch,
                        adaptive_batch: false,
                        enum_shards,
                        shortcut,
                        ..Default::default()
                    };
                    let got = compute_ph_from_filtration(f, &opts).diagram;
                    assert!(
                        got.multiset_eq(&want, 0.0),
                        "{label} threads={threads} shards={enum_shards} batch={batch} shortcut={shortcut}:\n{}",
                        got.diff_summary(&want)
                    );
                }
            }
            // Adaptive batching walks through many sizes in one run; the
            // output must not depend on the trajectory (nor on a shard
            // plan misaligned with the batch trajectory).
            let opts = EngineOptions {
                max_dim,
                threads,
                batch_size: 16,
                adaptive_batch: true,
                batch_min: 2,
                batch_max: 64,
                enum_shards: 3,
                shortcut,
                ..Default::default()
            };
            let got = compute_ph_from_filtration(f, &opts).diagram;
            assert!(
                got.multiset_eq(&want, 0.0),
                "{label} threads={threads} adaptive shortcut={shortcut}:\n{}",
                got.diff_summary(&want)
            );
        }
    }
}

#[test]
fn differential_scheduler_vs_oracle_small_dim2() {
    // Dense-ish dim-2 instances: H0/H1/H2 all populated.
    for seed in 0..3u64 {
        let mut rng = Pcg32::new(0xD1FF + seed);
        let data = random_cloud(&mut rng, 48, 3);
        let tau = rng.uniform(0.45, 0.6);
        let f = EdgeFiltration::build(&data, tau);
        check_instance(&f, 2, &format!("dim2 seed={seed} tau={tau}"));
    }
}

#[test]
fn differential_scheduler_vs_oracle_mid_dim2() {
    for seed in 0..2u64 {
        let mut rng = Pcg32::new(0xD1FF + 100 + seed);
        let data = random_cloud(&mut rng, 90, 2);
        let tau = rng.uniform(0.2, 0.28);
        let f = EdgeFiltration::build(&data, tau);
        check_instance(&f, 2, &format!("mid seed={seed} tau={tau}"));
    }
}

#[test]
fn differential_scheduler_vs_oracle_n200_h1() {
    // The ISSUE-sized instances: n = 200, d = 3, H1 (many batches at
    // batch=1/7, real intra-batch collisions at batch=100).
    for seed in 0..2u64 {
        let mut rng = Pcg32::new(0xD1FF + 200 + seed);
        let data = random_cloud(&mut rng, 200, 3);
        let tau = rng.uniform(0.22, 0.28);
        let f = EdgeFiltration::build(&data, tau);
        check_instance(&f, 1, &format!("n200 seed={seed} tau={tau}"));
    }
}

#[test]
fn differential_scheduler_vs_oracle_sparse_graph() {
    // Non-metric sparse input (the Hi-C shape): weights are arbitrary,
    // so pivot collisions cluster differently than in metric clouds.
    for seed in 0..3u64 {
        let mut rng = Pcg32::new(0x5AA5 + seed);
        let n = 60 + rng.gen_range(40);
        let mut entries = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < 0.25 {
                    entries.push((i, j, rng.uniform(0.05, 1.0)));
                }
            }
        }
        let data = MetricData::Sparse(SparseDistances {
            n: n as usize,
            entries,
        });
        let f = EdgeFiltration::build(&data, f64::INFINITY);
        check_instance(&f, 2, &format!("graph seed={seed} n={n}"));
    }
}

#[test]
fn differential_pipelined_reduction_structurally_exact() {
    // Below the diagram: the scheduler's ReduceResult (pairs, essential
    // columns, trivial counts) must equal the sequential fast-column
    // engine's bit for bit, for every pool size, batch size, steal grain
    // and adaptive trajectory.
    for seed in 0..3u64 {
        let mut rng = Pcg32::new(0xEAC7 + seed);
        let coords = (0..120 * 3).map(|_| rng.next_f64()).collect();
        let f = EdgeFiltration::build(
            &MetricData::Points(PointCloud::new(3, coords)),
            0.45,
        );
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
        let seq = fast_column::reduce_all(
            &space,
            cols.iter().copied(),
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        let mut seq_pairs = seq.pairs.clone();
        seq_pairs.sort_unstable();
        let mut seq_ess = seq.essential.clone();
        seq_ess.sort_unstable();

        for threads in THREADS {
            let pool = ThreadPool::new(threads);
            let mut cfgs: Vec<SchedConfig> = Vec::new();
            for batch in BATCHES {
                for grain in [0usize, 1] {
                    cfgs.push(SchedConfig {
                        batch_size: batch,
                        adaptive: false,
                        steal_grain: grain,
                        ..Default::default()
                    });
                }
            }
            cfgs.push(SchedConfig {
                batch_size: 8,
                adaptive: true,
                batch_min: 2,
                batch_max: 32,
                steal_grain: 0,
                ..Default::default()
            });
            for cfg in cfgs {
                let par = serial_parallel::reduce_all(
                    &space,
                    &cols,
                    &cfg,
                    &pool,
                    true,
                    |c| f.values[c as usize],
                    |k| f.key_value(k),
                );
                let mut pairs = par.pairs.clone();
                pairs.sort_unstable();
                let mut ess = par.essential.clone();
                ess.sort_unstable();
                assert_eq!(
                    pairs, seq_pairs,
                    "seed={seed} threads={threads} cfg={cfg:?}: pairs diverge"
                );
                assert_eq!(
                    ess, seq_ess,
                    "seed={seed} threads={threads} cfg={cfg:?}: essentials diverge"
                );
                assert_eq!(
                    par.stats.trivial_pairs, seq.stats.trivial_pairs,
                    "seed={seed} threads={threads} cfg={cfg:?}: trivial pairs diverge"
                );
                assert_eq!(
                    par.stats.pairs, seq.stats.pairs,
                    "seed={seed} threads={threads} cfg={cfg:?}: pair counts diverge"
                );
                assert_eq!(par.stats.columns, cols.len());
            }
        }
    }
}

#[test]
fn sharded_enumeration_byte_identical_over_40_seeds() {
    // The H2* column stream: for every diameter edge (descending) the
    // triangles ⟨e, v⟩ with secondary descending. The reference sequence
    // is rebuilt from `brute_force_coboundary` (a triangle has diameter
    // e iff its key in δe has primary e), entirely independently of the
    // cursor/merge machinery the sharded enumeration uses. Every shard
    // plan — filled inline or concurrently on the pool — must reproduce
    // it byte for byte.
    let pool = ThreadPool::new(4);
    for seed in 0..40u64 {
        let mut rng = Pcg32::new(0x5EED + seed);
        let n = 12 + rng.gen_range(9) as usize;
        let data = random_cloud(&mut rng, n, 3);
        let tau = rng.uniform(0.6, 1.1);
        let f = EdgeFiltration::build(&data, tau);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges();
        let mut want: Vec<u64> = Vec::new();
        for e in (0..ne as u32).rev() {
            let keys = brute_force_coboundary(&nb, &f, e);
            for k in keys.iter().rev().filter(|k| k.p == e) {
                want.push(k.pack());
            }
        }
        for (enum_shards, enum_grain) in [(1usize, 0usize), (2, 0), (3, 0), (7, 0), (16, 0), (0, 1), (0, 4)] {
            let plan = shard_plan(ne, 4, enum_shards, enum_grain);
            // Inline, shard order.
            let mut got: Vec<u64> = Vec::new();
            for r in &plan {
                triangles_with_diameter_in_range(&nb, &f, r.clone(), |_| true, &mut got);
            }
            assert_eq!(
                got, want,
                "seed={seed} shards={enum_shards} grain={enum_grain}: inline stream diverges"
            );
            // Concurrently on the pool, spliced back in shard order.
            let slots: Vec<std::sync::Mutex<Vec<u64>>> =
                plan.iter().map(|_| std::sync::Mutex::new(Vec::new())).collect();
            pool.run_stealing(plan.len(), 1, |_tid, range| {
                for s in range {
                    let mut buf = slots[s].lock().unwrap();
                    triangles_with_diameter_in_range(&nb, &f, plan[s].clone(), |_| true, &mut buf);
                }
            });
            let mut pooled: Vec<u64> = Vec::new();
            for s in slots {
                pooled.append(&mut s.into_inner().unwrap());
            }
            assert_eq!(
                pooled, want,
                "seed={seed} shards={enum_shards} grain={enum_grain}: pooled stream diverges"
            );
        }
    }
}

#[test]
fn shortcut_property_every_skipped_pair_has_zero_persistence() {
    // Two halves. (a) Property: every column the in-shard shortcut
    // would skip is an apparent pair — its minimal cofacet shares its
    // diameter, so birth == death to the bit — and the round-trip is
    // consistent with the cursor machinery. (b) Accounting: the engine's
    // shortcut counter equals an independent recount of the apparent,
    // non-cleared columns, and on/off runs agree bit for bit with
    // trivial totals invariant.
    for seed in 0..4u64 {
        let mut rng = Pcg32::new(0xA44A + seed);
        let data = random_cloud(&mut rng, 40, 3);
        let tau = rng.uniform(0.5, 0.75);
        let f = EdgeFiltration::build(&data, tau);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges() as u32;

        // (a) H2*: the apparent property over the full triangle universe.
        let mut tris: Vec<u64> = Vec::new();
        triangles_with_diameter_in_range(&nb, &f, 0..ne, |_| true, &mut tris);
        for &p in &tris {
            let t = Key::unpack(p);
            if let Some(h) = apparent_cofacet(&nb, &f, t) {
                assert_eq!(h.p, t.p, "seed={seed} t={t}: diameters must match");
                assert_eq!(
                    f.key_value(t).to_bits(),
                    f.key_value(h).to_bits(),
                    "seed={seed} t={t}: skipped pair must have birth == death"
                );
                assert_eq!(max_equal_facet_of_tet(&f, h), t, "seed={seed} t={t}");
                assert_eq!(TetCursor::find_smallest(&nb, &f, t).cur, h, "seed={seed}");
            }
        }
        // (a) H1*: same property for edge columns.
        let space = EdgeColumns::new(&nb, &f);
        for e in 0..ne {
            if is_apparent_edge_pair(e, space.smallest_tri[e as usize]) {
                let t = space.smallest_tri[e as usize];
                assert_eq!(
                    f.values[e as usize].to_bits(),
                    f.key_value(t).to_bits(),
                    "seed={seed} e={e}: skipped pair must have birth == death"
                );
            }
        }

        // (b) Engine accounting, threaded and sequential.
        for threads in [1usize, 4] {
            let mk = |shortcut: bool| EngineOptions {
                max_dim: 2,
                threads,
                shortcut,
                ..Default::default()
            };
            let on = compute_ph_from_filtration(&f, &mk(true));
            let off = compute_ph_from_filtration(&f, &mk(false));
            assert!(
                on.diagram.multiset_eq(&off.diagram, 0.0),
                "seed={seed} threads={threads}: shortcut changed the diagram"
            );
            assert_eq!(on.stats.h1.trivial_pairs, off.stats.h1.trivial_pairs);
            assert_eq!(on.stats.h2.trivial_pairs, off.stats.h2.trivial_pairs);
            assert_eq!(
                on.stats.h1.columns + on.stats.h1.shortcut_pairs,
                off.stats.h1.columns,
                "seed={seed} threads={threads}"
            );
            assert_eq!(
                on.stats.h2.columns + on.stats.h2.shortcut_pairs,
                off.stats.h2.columns,
                "seed={seed} threads={threads}"
            );
            // Independent recount of what the H2* shard filter skips:
            // apparent triangles that survive trivial-death and
            // H1-death clearing.
            let h1_deaths: std::collections::HashSet<u64> =
                on.h1_pairs.iter().map(|&(_, k)| k.pack()).collect();
            let expected_h2: usize = tris
                .iter()
                .filter(|&&p| {
                    let t = Key::unpack(p);
                    space.smallest_tri[t.p as usize] != t
                        && !h1_deaths.contains(&p)
                        && apparent_cofacet(&nb, &f, t).is_some()
                })
                .count();
            assert_eq!(
                on.stats.h2.shortcut_pairs, expected_h2,
                "seed={seed} threads={threads}: H2* shortcut recount"
            );
            assert!(
                on.stats.h2.shortcut_pairs > 0,
                "seed={seed} threads={threads}: expected apparent H2* pairs"
            );
        }
    }
}

#[test]
fn engine_pool_reuse_stress_h1_h2_20_rounds() {
    // One Engine, one pool, 20 back-to-back H0→H1*→H2* runs: output must
    // stay bit-identical, the pool must accept fresh generations after
    // every run (no stuck in-flight state), and — with adaptation off —
    // the generation accounting must advance by the same amount each
    // round (a straggler or leaked ticket would skew it).
    let mut rng = Pcg32::new(0x9001);
    let data = random_cloud(&mut rng, 40, 3);
    let f = EdgeFiltration::build(&data, 0.55);
    let engine = Engine::new(EngineOptions {
        max_dim: 2,
        threads: 4,
        batch_size: 13,
        adaptive_batch: false,
        enum_shards: 6,
        ..Default::default()
    });
    let pool_stats = |e: &Engine| e.pool().unwrap().stats();
    let reference = engine.compute(&f);
    assert!(
        reference.stats.h2_sched.enum_shards > 0,
        "H2* enumeration must run on the pool"
    );
    let mut last_gens = pool_stats(&engine).generations;
    let per_run = last_gens;
    let mut deltas = Vec::new();
    for round in 0..20 {
        let r = engine.compute(&f);
        assert!(
            r.diagram.multiset_eq(&reference.diagram, 0.0),
            "round={round}: diagram deviates on a reused pool"
        );
        assert_eq!(
            r.stats.h2_sched.enum_columns, reference.stats.h2_sched.enum_columns,
            "round={round}"
        );
        let gens = pool_stats(&engine).generations;
        deltas.push(gens - last_gens);
        last_gens = gens;
        // The pool must be cleanly reusable right now: an extra empty
        // generation completes without touching the run's state.
        engine.pool().unwrap().run_stealing(0, 1, |_t, _r| {});
        last_gens += 1;
    }
    assert!(
        deltas.iter().all(|&d| d == per_run),
        "generation counters must advance identically each round: first={per_run} deltas={deltas:?}"
    );
}

#[test]
fn differential_repeated_schedules_are_deterministic() {
    // Steal schedules differ run to run; the output may not. Hammer one
    // instance with a racy configuration (tiny grain, many threads) and
    // require identical output every time.
    let mut rng = Pcg32::new(0xBADC0DE);
    let coords = (0..80 * 3).map(|_| rng.next_f64()).collect();
    let f = EdgeFiltration::build(&MetricData::Points(PointCloud::new(3, coords)), 0.5);
    let nb = Neighborhoods::build(&f, false);
    let space = EdgeColumns::new(&nb, &f);
    let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
    let cfg = SchedConfig {
        batch_size: 13,
        adaptive: false,
        steal_grain: 1,
        ..Default::default()
    };
    let pool = ThreadPool::new(8);
    let reference = serial_parallel::reduce_all(
        &space,
        &cols,
        &cfg,
        &pool,
        true,
        |c| f.values[c as usize],
        |k| f.key_value(k),
    );
    for round in 0..15 {
        let r = serial_parallel::reduce_all(
            &space,
            &cols,
            &cfg,
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        assert_eq!(r.pairs, reference.pairs, "round={round}");
        assert_eq!(r.essential, reference.essential, "round={round}");
    }
}
