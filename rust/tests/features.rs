//! Feature-products subsystem harness.
//!
//! Pins the contract of `dory::features` end to end:
//!
//! * **golden cross-validation** — Betti curves, entropy, landscapes and
//!   persistence images served by the session must match the values an
//!   independent Python implementation computed from the same diagram
//!   (`fixtures/generate_fixtures.py`, `*.features.txt`): integer curves
//!   exactly, float kernels at 1e-12 relative tolerance (the only
//!   permitted deviation is a libm ulp in `exp`/`ln`);
//! * **bit identity** — every feature payload is byte-identical across
//!   thread counts × batch schedules, and for cached-handle vs
//!   fresh-ingest queries, with the session's build counters proving
//!   feature requests never trigger a rebuild;
//! * **properties** — entropy is permutation-invariant at the bit
//!   level, landscapes are non-negative / 1-Lipschitz / monotone in the
//!   level, Betti curves equal independent event counts at every
//!   sample;
//! * **essential semantics** — death = ∞ classes are clamped to the
//!   span, counted in `FeatureStats::clamped_points`, and never leak a
//!   NaN/∞ into a finite kernel;
//! * **representatives** — served loops are genuine closed walks of
//!   birth-time edges, anchored on the birth edge, with the advertised
//!   perimeter.

use std::path::{Path, PathBuf};

use dory::features::{self, clamped_sorted, FeatureSpec, FeatureValue};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{Diagram, EngineOptions, PhRequest, PhResponse, Session};
use dory::util::rng::Pcg32;

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

fn parse_hex_f64(s: &str) -> f64 {
    f64::from_bits(u64::from_str_radix(s, 16).unwrap_or_else(|e| panic!("bad hex {s}: {e}")))
}

/// The input slice of a `*.pd.txt` fixture (the expected-PD lines are
/// golden_pd.rs's business; features only need the exact input).
struct PdInput {
    max_dim: usize,
    tau: f64,
    data: MetricData,
}

fn load_pd_input(path: &Path) -> PdInput {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
    let mut kind = String::new();
    let mut max_dim = 0usize;
    let mut tau = f64::INFINITY;
    let mut dim = 0usize;
    let mut n = 0usize;
    let mut coords: Vec<f64> = Vec::new();
    let mut entries: Vec<(u32, u32, f64)> = Vec::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("kind") => kind = it.next().unwrap().to_string(),
            Some("max_dim") => max_dim = it.next().unwrap().parse().unwrap(),
            Some("tau") => tau = parse_hex_f64(it.next().unwrap()),
            Some("dim") => dim = it.next().unwrap().parse().unwrap(),
            Some("n") => n = it.next().unwrap().parse().unwrap(),
            Some("point") => coords.extend(it.map(parse_hex_f64)),
            Some("entry") => {
                let u: u32 = it.next().unwrap().parse().unwrap();
                let v: u32 = it.next().unwrap().parse().unwrap();
                entries.push((u, v, parse_hex_f64(it.next().unwrap())));
            }
            _ => {}
        }
    }
    let data = match kind.as_str() {
        "points" => MetricData::Points(PointCloud::new(dim, coords)),
        "sparse" => MetricData::Sparse(SparseDistances { n, entries }),
        other => panic!("{path:?}: unknown kind {other}"),
    };
    PdInput { max_dim, tau, data }
}

/// A `*.features.txt` golden fixture: the Python-computed expectations.
struct FeatureFixture {
    span: f64,
    max_dim: usize,
    betti_grid: usize,
    landscape_levels: usize,
    landscape_grid: usize,
    image_grid: usize,
    /// per dim
    clamped: Vec<u64>,
    /// `[dim][sample]`
    betti: Vec<Vec<u64>>,
    /// `[dim]`
    entropy: Vec<f64>,
    /// `[dim][level][sample]`
    landscape: Vec<Vec<Vec<f64>>>,
    /// `[dim][row*grid + col]`
    image: Vec<Vec<f64>>,
}

fn load_feature_fixture(path: &Path) -> FeatureFixture {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
    let mut fx = FeatureFixture {
        span: 0.0,
        max_dim: 0,
        betti_grid: 0,
        landscape_levels: 0,
        landscape_grid: 0,
        image_grid: 0,
        clamped: Vec::new(),
        betti: Vec::new(),
        entropy: Vec::new(),
        landscape: Vec::new(),
        image: Vec::new(),
    };
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let Some(tag) = it.next() else { continue };
        match tag {
            "span" => fx.span = parse_hex_f64(it.next().unwrap()),
            "max_dim" => {
                fx.max_dim = it.next().unwrap().parse().unwrap();
                let nd = fx.max_dim + 1;
                fx.clamped = vec![0; nd];
                fx.betti = vec![Vec::new(); nd];
                fx.entropy = vec![0.0; nd];
                fx.landscape = vec![Vec::new(); nd];
                fx.image = vec![Vec::new(); nd];
            }
            "betti_grid" => fx.betti_grid = it.next().unwrap().parse().unwrap(),
            "landscape_levels" => fx.landscape_levels = it.next().unwrap().parse().unwrap(),
            "landscape_grid" => fx.landscape_grid = it.next().unwrap().parse().unwrap(),
            "image_grid" => fx.image_grid = it.next().unwrap().parse().unwrap(),
            "clamped" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                fx.clamped[d] = it.next().unwrap().parse().unwrap();
            }
            "betti" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                fx.betti[d] = it.map(|v| v.parse().unwrap()).collect();
            }
            "entropy" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                fx.entropy[d] = parse_hex_f64(it.next().unwrap());
            }
            "landscape" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                let k: usize = it.next().unwrap().parse().unwrap();
                let row: Vec<f64> = it.map(parse_hex_f64).collect();
                assert_eq!(fx.landscape[d].len(), k, "landscape rows out of order");
                fx.landscape[d].push(row);
            }
            "image" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                let r: usize = it.next().unwrap().parse().unwrap();
                assert_eq!(fx.image[d].len(), r * fx.image_grid, "image rows out of order");
                fx.image[d].extend(it.map(parse_hex_f64));
            }
            _ => {}
        }
    }
    fx
}

/// `|got - want| <= 1e-12 · max(1, |want|)` — room for exactly a libm
/// ulp difference between Python's and Rust's `exp`/`ln`, nothing more.
fn assert_close(got: f64, want: f64, what: &str) {
    assert!(
        (got - want).abs() <= 1e-12 * want.abs().max(1.0),
        "{what}: got {got:e}, want {want:e} (diff {:e})",
        (got - want).abs()
    );
}

/// Flatten every feature payload (and the span) to raw bits, for
/// byte-level identity comparisons across configurations.
fn feature_bits(resp: &PhResponse) -> Vec<u64> {
    let fo = resp.features.as_ref().expect("features requested");
    let mut bits = vec![fo.span.to_bits()];
    for item in &fo.items {
        bits.extend(item.spec.name().bytes().map(u64::from));
        match &item.value {
            FeatureValue::BettiCurve(dims) => {
                for d in dims {
                    bits.extend(d.iter().copied());
                }
            }
            FeatureValue::Entropy(dims) => bits.extend(dims.iter().map(|v| v.to_bits())),
            FeatureValue::Landscape(dims) => {
                for levels in dims {
                    for level in levels {
                        bits.extend(level.iter().map(|v| v.to_bits()));
                    }
                }
            }
            FeatureValue::Image(dims) => {
                for img in dims {
                    bits.extend(img.iter().map(|v| v.to_bits()));
                }
            }
            FeatureValue::Representatives(cycles) => {
                for c in cycles {
                    bits.push(c.birth.to_bits());
                    bits.push(c.death.to_bits());
                    bits.push(c.perimeter.to_bits());
                    bits.push(u64::from(c.anchor.0) << 32 | u64::from(c.anchor.1));
                    bits.extend(c.vertices.iter().map(|&v| u64::from(v)));
                }
            }
        }
    }
    bits
}

fn cloud(n: usize, dim: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

// ---------------------------------------------------------------------
// Golden cross-validation against the Python implementation
// ---------------------------------------------------------------------

fn check_against_python(name: &str) {
    let input = load_pd_input(&fixtures_dir().join(format!("{name}.pd.txt")));
    let fx = load_feature_fixture(&fixtures_dir().join(format!("{name}.features.txt")));
    let session = Session::new(EngineOptions {
        max_dim: input.max_dim,
        threads: 2,
        ..Default::default()
    });
    let handle = session.ingest(&input.data, input.tau).unwrap();
    let specs = vec![
        FeatureSpec::BettiCurve { grid: fx.betti_grid },
        FeatureSpec::Entropy,
        FeatureSpec::Landscape {
            levels: fx.landscape_levels,
            grid: fx.landscape_grid,
        },
        FeatureSpec::Image { grid: fx.image_grid },
    ];
    let resp = session
        .query(
            &handle,
            &PhRequest {
                tau: input.tau,
                features: specs,
                ..Default::default()
            },
        )
        .unwrap();
    let fo = resp.features.as_ref().expect("features must be served");
    assert_eq!(fo.span.to_bits(), fx.span.to_bits(), "{name}: span");
    assert_eq!(fo.items.len(), 4);
    let ndims = fx.max_dim + 1;
    // Three clamping kernels (entropy, landscape, image) each clamp
    // every essential class once.
    let clamped_per_pass: u64 = fx.clamped.iter().sum();
    assert_eq!(
        fo.stats.clamped_points,
        3 * clamped_per_pass,
        "{name}: clamped_points"
    );
    match &fo.items[0].value {
        FeatureValue::BettiCurve(dims) => {
            assert_eq!(dims.len(), ndims);
            for d in 0..ndims {
                assert_eq!(dims[d], fx.betti[d], "{name}: betti dim {d}");
            }
        }
        other => panic!("{name}: expected BettiCurve, got {other:?}"),
    }
    match &fo.items[1].value {
        FeatureValue::Entropy(dims) => {
            for d in 0..ndims {
                assert_close(dims[d], fx.entropy[d], &format!("{name}: entropy dim {d}"));
            }
        }
        other => panic!("{name}: expected Entropy, got {other:?}"),
    }
    match &fo.items[2].value {
        FeatureValue::Landscape(dims) => {
            for d in 0..ndims {
                assert_eq!(dims[d].len(), fx.landscape_levels);
                for (k, level) in dims[d].iter().enumerate() {
                    assert_eq!(level.len(), fx.landscape_grid + 1);
                    for (i, &v) in level.iter().enumerate() {
                        assert_close(
                            v,
                            fx.landscape[d][k][i],
                            &format!("{name}: landscape dim {d} level {k} sample {i}"),
                        );
                    }
                }
            }
        }
        other => panic!("{name}: expected Landscape, got {other:?}"),
    }
    match &fo.items[3].value {
        FeatureValue::Image(dims) => {
            for d in 0..ndims {
                assert_eq!(dims[d].len(), fx.image_grid * fx.image_grid);
                for (i, &v) in dims[d].iter().enumerate() {
                    assert_close(
                        v,
                        fx.image[d][i],
                        &format!("{name}: image dim {d} pixel {i}"),
                    );
                    assert!(v.is_finite(), "{name}: image dim {d} pixel {i} not finite");
                }
            }
        }
        other => panic!("{name}: expected Image, got {other:?}"),
    }
}

#[test]
fn golden_features_circle48_match_python() {
    check_against_python("circle48");
}

#[test]
fn golden_features_hic240_match_python() {
    check_against_python("hic240");
}

// ---------------------------------------------------------------------
// Bit identity across schedules and ingest paths
// ---------------------------------------------------------------------

#[test]
fn features_bit_identical_across_threads_and_batches() {
    let data = cloud(40, 3, 2026);
    let tau = 0.9;
    let specs = vec![
        FeatureSpec::BettiCurve { grid: 12 },
        FeatureSpec::Entropy,
        FeatureSpec::Landscape { levels: 3, grid: 10 },
        FeatureSpec::Image { grid: 12 },
        FeatureSpec::Representatives { min_persistence: 0.0 },
    ];
    let mut reference: Option<Vec<u64>> = None;
    for threads in [1usize, 2, 8] {
        for (batch_size, adaptive) in [(32usize, true), (7, false), (100, false)] {
            let session = Session::new(EngineOptions {
                max_dim: 1,
                threads,
                batch_size,
                adaptive_batch: adaptive,
                ..Default::default()
            });
            let handle = session.ingest(&data, tau).unwrap();
            let resp = session
                .query(
                    &handle,
                    &PhRequest {
                        tau,
                        features: specs.clone(),
                        ..Default::default()
                    },
                )
                .unwrap();
            let bits = feature_bits(&resp);
            match &reference {
                None => reference = Some(bits),
                Some(want) => assert_eq!(
                    &bits, want,
                    "threads={threads} batch={batch_size} adaptive={adaptive}: \
                     feature bytes deviate"
                ),
            }
        }
    }
}

#[test]
fn cached_handle_features_match_fresh_ingest_and_never_rebuild() {
    let data = cloud(36, 3, 7171);
    let specs = vec![
        FeatureSpec::Entropy,
        FeatureSpec::Image { grid: 8 },
        FeatureSpec::Representatives { min_persistence: 0.0 },
    ];
    let opts = EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    };
    // One cached handle serving three τ-cuts with features...
    let cached = Session::new(opts.clone());
    let handle = cached.ingest(&data, 0.9).unwrap();
    let taus = [0.4, 0.7, 0.9];
    let mut served = Vec::new();
    for &tau in &taus {
        let resp = cached
            .query(
                &handle,
                &PhRequest {
                    tau,
                    features: specs.clone(),
                    ..Default::default()
                },
            )
            .unwrap();
        served.push(feature_bits(&resp));
    }
    // ... feature queries ride the shared build: still exactly one.
    assert_eq!(cached.stats().filtration_builds, 1);
    assert_eq!(cached.stats().nb_builds, 1);
    assert_eq!(cached.stats().feature_queries, taus.len() as u64);
    // ... must serve byte-identical features to fresh per-τ ingests.
    for (i, &tau) in taus.iter().enumerate() {
        let fresh = Session::new(opts.clone());
        let h = fresh.ingest(&data, tau).unwrap();
        let resp = fresh
            .query(
                &h,
                &PhRequest {
                    tau,
                    features: specs.clone(),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(
            feature_bits(&resp),
            served[i],
            "tau={tau}: cached-handle features deviate from fresh ingest"
        );
    }
}

// ---------------------------------------------------------------------
// Kernel properties
// ---------------------------------------------------------------------

/// A reproducible random diagram: `k` classes in `[0, span]`, a fraction
/// essential.
fn random_diagram(k: usize, span: f64, seed: u64) -> Diagram {
    let mut rng = Pcg32::new(seed);
    let mut d = Diagram::new(1);
    for _ in 0..k {
        let b = rng.uniform(0.0, span * 0.8);
        if rng.next_f64() < 0.15 {
            d.push(1, b, f64::INFINITY);
        } else {
            d.push(1, b, b + rng.uniform(0.0, span - b));
        }
    }
    d
}

#[test]
fn entropy_is_permutation_invariant_at_the_bit_level() {
    let span = 2.0;
    for seed in [1u64, 2, 3] {
        let d = random_diagram(17, span, seed);
        let (pts, _) = clamped_sorted(&d, 1, span);
        let want = features::entropy::entropy(&pts).to_bits();
        // Re-push the same points in reversed and rotated orders: the
        // canonical sort must erase the permutation entirely.
        let points: Vec<_> = d.points(1).to_vec();
        for rot in [1usize, 5, 11] {
            let mut perm = Diagram::new(1);
            for i in 0..points.len() {
                let p = &points[(i * rot + 3) % points.len()];
                perm.push(1, p.birth, p.death);
            }
            let (pp, _) = clamped_sorted(&perm, 1, span);
            assert_eq!(
                features::entropy::entropy(&pp).to_bits(),
                want,
                "seed={seed} rot={rot}"
            );
        }
    }
}

#[test]
fn landscapes_are_nonnegative_lipschitz_and_nested() {
    let span = 1.5;
    let grid = 64usize;
    let levels = 4usize;
    let step = span / grid as f64;
    for seed in [11u64, 12, 13] {
        let d = random_diagram(23, span, seed);
        let (pts, _) = clamped_sorted(&d, 1, span);
        let ls = features::landscape::landscape(&pts, levels, grid, span);
        assert_eq!(ls.len(), levels);
        for (k, level) in ls.iter().enumerate() {
            assert_eq!(level.len(), grid + 1);
            for (i, &v) in level.iter().enumerate() {
                assert!(v >= 0.0, "seed={seed} λ_{k}[{i}] = {v} < 0");
                assert!(v.is_finite());
                if i > 0 {
                    // 1-Lipschitz: every tent has slope ±1.
                    assert!(
                        (v - level[i - 1]).abs() <= step + 1e-12,
                        "seed={seed} λ_{k} jumps {} > step {step} at {i}",
                        (v - level[i - 1]).abs()
                    );
                }
                // Levels are nested: λ_k ≥ λ_{k+1} pointwise.
                if k > 0 {
                    assert!(ls[k - 1][i] >= v, "seed={seed} λ_{} < λ_{k} at {i}", k - 1);
                }
            }
        }
    }
}

#[test]
fn betti_curve_equals_event_counts_at_every_sample() {
    let span = 2.5;
    let grid = 37usize;
    for seed in [21u64, 22] {
        let d = random_diagram(29, span, seed);
        let curve = features::betti::curve(&d, 1, grid, span);
        assert_eq!(curve.len(), grid + 1);
        for (i, &got) in curve.iter().enumerate() {
            let t = span * i as f64 / grid as f64;
            // Independent event count straight off the diagram: alive
            // means birth ≤ t < death (essentials never die).
            let want = d
                .points(1)
                .iter()
                .filter(|p| p.birth <= t && t < p.death)
                .count() as u64;
            assert_eq!(got, want, "seed={seed} sample {i} (t={t})");
        }
    }
}

// ---------------------------------------------------------------------
// Essential-class semantics
// ---------------------------------------------------------------------

#[test]
fn essential_classes_clamp_to_span_and_stay_finite() {
    // Two well-separated clusters: 2 essential H0 classes at every τ
    // below the gap, so every clamping kernel must fire.
    let mut rng = Pcg32::new(404);
    let mut coords = Vec::new();
    for i in 0..30 {
        let off = if i < 15 { 0.0 } else { 50.0 };
        coords.extend([off + rng.next_f64(), rng.next_f64()]);
    }
    let data = MetricData::Points(PointCloud::new(2, coords));
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let handle = session.ingest(&data, 3.0).unwrap();
    let resp = session
        .query(
            &handle,
            &PhRequest {
                tau: 3.0,
                features: vec![
                    FeatureSpec::Entropy,
                    FeatureSpec::Landscape { levels: 2, grid: 8 },
                    FeatureSpec::Image { grid: 8 },
                ],
                ..Default::default()
            },
        )
        .unwrap();
    let fo = resp.features.as_ref().unwrap();
    // 2 essential H0 classes × 3 clamping kernels, at least.
    assert!(
        fo.stats.clamped_points >= 6,
        "clamped_points = {}",
        fo.stats.clamped_points
    );
    for item in &fo.items {
        match &item.value {
            FeatureValue::Entropy(dims) => {
                assert!(dims.iter().all(|v| v.is_finite()), "{dims:?}")
            }
            FeatureValue::Landscape(dims) => {
                for levels in dims {
                    for level in levels {
                        assert!(level.iter().all(|v| v.is_finite()), "{level:?}");
                    }
                }
            }
            FeatureValue::Image(dims) => {
                for img in dims {
                    assert!(img.iter().all(|v| v.is_finite()));
                }
            }
            other => panic!("unexpected payload {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// Representatives, end to end
// ---------------------------------------------------------------------

#[test]
fn served_representatives_are_valid_closed_walks() {
    let data = dory::datasets::figure_eight(80, 1.0, 0.0, 2);
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let handle = session.ingest(&data, 1.2).unwrap();
    let min_persistence = 0.4;
    let resp = session
        .query(
            &handle,
            &PhRequest {
                tau: 1.2,
                features: vec![FeatureSpec::Representatives { min_persistence }],
                ..Default::default()
            },
        )
        .unwrap();
    let fo = resp.features.as_ref().unwrap();
    let FeatureValue::Representatives(cycles) = &fo.items[0].value else {
        panic!("expected Representatives");
    };
    assert_eq!(cycles.len(), 2, "figure eight carries two loops");
    assert_eq!(fo.stats.cycles, 2);
    let nb = handle.neighborhoods();
    let f = handle.filtration();
    for c in cycles {
        let n = c.vertices.len();
        assert!(n >= 3, "loop too short: {n}");
        assert!(c.persistence() > min_persistence);
        assert_eq!(c.anchor.0, *c.vertices.first().unwrap());
        assert_eq!(c.anchor.1, *c.vertices.last().unwrap());
        // Genuine closed walk of birth-time edges, and the advertised
        // perimeter is exactly the sum of its edge values.
        let mut per = 0.0f64;
        for i in 0..n {
            let (u, v) = (c.vertices[i], c.vertices[(i + 1) % n]);
            let o = nb
                .edge_order(u, v)
                .unwrap_or_else(|| panic!("cycle edge ({u}, {v}) missing"));
            assert!(
                f.values[o as usize] <= c.birth + 1e-12,
                "edge ({u}, {v}) enters after birth"
            );
            per += f.values[o as usize];
        }
        assert_eq!(per.to_bits(), c.perimeter.to_bits(), "perimeter mismatch");
        let set: std::collections::HashSet<_> = c.vertices.iter().collect();
        assert_eq!(set.len(), n, "repeated vertex in representative");
    }
    // The canonical order is (birth, death, anchor), ascending.
    for w in cycles.windows(2) {
        assert!(
            (w[0].birth, w[0].death) <= (w[1].birth, w[1].death),
            "cycles out of canonical order"
        );
    }
}

#[test]
fn feature_requests_on_sub_tau_cuts_use_the_served_view() {
    // Representatives on a truncated cut must measure against the cut's
    // own view — every emitted loop is fully present at the cut.
    let data = dory::datasets::circle(48, 1.0, 0.05, 1);
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let handle = session.ingest(&data, 3.0).unwrap();
    for tau in [0.7, 1.5, 3.0] {
        let resp = session
            .query(
                &handle,
                &PhRequest {
                    tau,
                    features: vec![
                        FeatureSpec::Representatives { min_persistence: 0.3 },
                        FeatureSpec::Entropy,
                    ],
                    ..Default::default()
                },
            )
            .unwrap();
        let fo = resp.features.as_ref().unwrap();
        assert_eq!(fo.span.to_bits(), tau.to_bits(), "tau={tau}: span is the cut");
        let FeatureValue::Representatives(cycles) = &fo.items[0].value else {
            panic!("expected Representatives");
        };
        assert!(!cycles.is_empty(), "tau={tau}: the dominant loop is long-lived");
        for c in cycles {
            assert!(c.birth <= tau, "tau={tau}: birth beyond the cut");
            assert!(c.perimeter.is_finite());
        }
    }
    assert_eq!(session.stats().filtration_builds, 1);
}
