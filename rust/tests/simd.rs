//! Differential verification of the SIMD distance microkernel.
//!
//! The kernel contract: every `simd` mode — scalar loop, runtime-probed
//! auto, forced AVX2/NEON (degrading to scalar when the CPU lacks the
//! feature) — emits **bit-identical** edge sets and persistence
//! diagrams (tol 0). The sweep covers every lane-remainder class
//! (n mod 8 ∈ {0..7} at 2 and 4 lanes), low and high dimensions, and
//! coordinates mixing ±0.0 and subnormals, where a reassociated or
//! FMA-contracted sum would diverge in the last ulp.

use dory::filtration::{EdgeFiltration, FiltrationStats, FrontendOptions, SimdMode};
use dory::geometry::{MetricData, PointCloud};
use dory::homology::{compute_ph, Engine, EngineOptions};
use dory::reduction::pool::ThreadPool;
use dory::util::rng::Pcg32;

/// A point cloud salted with the coordinate values most likely to
/// expose a non-identical kernel: ±0.0 (sign of zero must not leak into
/// sums), subnormals (flush-to-zero hardware modes would diverge), and
/// ordinary values.
fn tricky_cloud(n: usize, dim: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let coords = (0..n * dim)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => -0.0,
            2 => 1e-310,
            3 => -1e-310,
            _ => rng.uniform(-1.0, 1.0),
        })
        .collect();
    MetricData::Points(PointCloud::new(dim, coords))
}

fn edge_bits(f: &EdgeFiltration) -> (Vec<(u32, u32)>, Vec<u64>) {
    (
        f.edges.clone(),
        f.values.iter().map(|v| v.to_bits()).collect(),
    )
}

/// Edge sets are bit-identical across every mode × lane-remainder class
/// × dimension × tile plan, at a finite τ and at τ = ∞ with the
/// enclosing truncation (which exercises the fused row-max path).
#[test]
fn simd_sweep_emits_bit_identical_edge_sets() {
    let pool = ThreadPool::new(4);
    let modes = [SimdMode::Auto, SimdMode::Avx2, SimdMode::Neon];
    for dim in [2usize, 3, 8, 20] {
        for n in 8usize..=16 {
            let data = tricky_cloud(n, dim, 0x51AD + (dim * 100 + n) as u64);
            for (tau, enclosing) in [(0.8, false), (f64::INFINITY, true)] {
                let base_fe = FrontendOptions {
                    tile: 0,
                    enclosing,
                    simd: SimdMode::Scalar,
                };
                let mut base_stats = FiltrationStats::default();
                let base = EdgeFiltration::build_pooled(
                    &data,
                    tau,
                    Some(&pool),
                    &base_fe,
                    &mut base_stats,
                );
                assert_eq!(base_stats.dist_kernel, "scalar");
                let (base_edges, base_vals) = edge_bits(&base);
                for mode in modes {
                    for tile in [0usize, 1, 3] {
                        let label = format!(
                            "dim={dim} n={n} tau={tau} mode={mode:?} tile={tile}"
                        );
                        let fe = FrontendOptions {
                            tile,
                            enclosing,
                            simd: mode,
                        };
                        let mut stats = FiltrationStats::default();
                        let f = EdgeFiltration::build_pooled(
                            &data,
                            tau,
                            Some(&pool),
                            &fe,
                            &mut stats,
                        );
                        let (edges, vals) = edge_bits(&f);
                        assert_eq!(base_edges, edges, "{label}: edge order");
                        assert_eq!(base_vals, vals, "{label}: value bits");
                        assert_eq!(
                            base.tau_max.to_bits(),
                            f.tau_max.to_bits(),
                            "{label}: tau_max"
                        );
                        assert_eq!(
                            base_stats.enclosing_radius.to_bits(),
                            stats.enclosing_radius.to_bits(),
                            "{label}: r_enc"
                        );
                        assert!(
                            ["scalar", "avx2", "neon"].contains(&stats.dist_kernel),
                            "{label}: kernel {:?}",
                            stats.dist_kernel
                        );
                    }
                }
            }
        }
    }
}

/// Diagrams are bit-identical (tol 0) across modes, through the full
/// engine (H0/H1) at every lane-remainder class.
#[test]
fn simd_sweep_emits_bit_identical_diagrams() {
    for dim in [2usize, 3, 8, 20] {
        for n in 8usize..=16 {
            let data = tricky_cloud(n, dim, 0xD1A6 + (dim * 100 + n) as u64);
            let mk = |mode: SimdMode| EngineOptions {
                max_dim: 1,
                threads: 2,
                simd: mode,
                ..Default::default()
            };
            let want = compute_ph(&data, f64::INFINITY, &mk(SimdMode::Scalar)).diagram;
            for mode in [SimdMode::Auto, SimdMode::Avx2, SimdMode::Neon] {
                let got = compute_ph(&data, f64::INFINITY, &mk(mode)).diagram;
                assert!(
                    got.multiset_eq(&want, 0.0),
                    "dim={dim} n={n} mode={mode:?}: diagram deviates from scalar"
                );
            }
        }
    }
}

/// Runtime feature detection: a forced mode whose vector extension the
/// build target cannot have degrades to the scalar path and says so in
/// `FiltrationStats::dist_kernel`; `Scalar` always reports scalar.
#[test]
fn forced_unavailable_modes_fall_back_to_scalar() {
    let data = tricky_cloud(24, 3, 0xFA11);
    let run = |mode: SimdMode| {
        let engine = Engine::new(EngineOptions {
            max_dim: 1,
            threads: 2,
            simd: mode,
            ..Default::default()
        });
        let r = engine.compute_metric(&data, f64::INFINITY);
        (r.stats.filtration.dist_kernel, r.diagram)
    };
    let (k_scalar, d_scalar) = run(SimdMode::Scalar);
    assert_eq!(k_scalar, "scalar");
    // The foreign architecture's mode can never be live here.
    #[cfg(target_arch = "x86_64")]
    let (k_foreign, d_foreign) = run(SimdMode::Neon);
    #[cfg(target_arch = "aarch64")]
    let (k_foreign, d_foreign) = run(SimdMode::Avx2);
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let (k_foreign, d_foreign) = run(SimdMode::Auto);
    assert_eq!(k_foreign, "scalar");
    assert!(d_foreign.multiset_eq(&d_scalar, 0.0));
    // Auto always selects something, and it is always bit-identical.
    let (k_auto, d_auto) = run(SimdMode::Auto);
    assert!(["scalar", "avx2", "neon"].contains(&k_auto), "{k_auto}");
    assert!(d_auto.multiset_eq(&d_scalar, 0.0));
}

/// The SimdMode knob parses exactly the documented names.
#[test]
fn simd_mode_parses_documented_names() {
    assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
    assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
    assert_eq!(SimdMode::parse("avx2"), Some(SimdMode::Avx2));
    assert_eq!(SimdMode::parse("neon"), Some(SimdMode::Neon));
    assert_eq!(SimdMode::parse("sse2"), None);
    assert_eq!(SimdMode::default(), SimdMode::Auto);
    for m in [SimdMode::Auto, SimdMode::Scalar, SimdMode::Avx2, SimdMode::Neon] {
        assert_eq!(SimdMode::parse(m.as_str()), Some(m));
    }
}
