//! Differential verification of the parallel filtration front-end.
//!
//! The tentpole guarantee: the pool-tiled distance kernel, the
//! total-order key sort and the pooled CSR fill are **byte-identical**
//! to the serial front-end for every tile plan, pool size and steal
//! schedule — and the enclosing-radius truncation changes the edge set
//! but never a persistence diagram (beyond `r_enc` the VR complex is a
//! cone). Failures print the seed for exact reproduction.

use dory::filtration::{EdgeFiltration, FiltrationStats, FrontendOptions, Neighborhoods};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph_from_filtration, Engine, EngineOptions};
use dory::reduction::pool::ThreadPool;
use dory::util::rng::Pcg32;

fn random_cloud(rng: &mut Pcg32, max_n: usize, dim: usize) -> MetricData {
    let n = 16 + rng.gen_range((max_n - 16) as u32) as usize;
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

fn random_graph(rng: &mut Pcg32, max_n: u32) -> MetricData {
    let n = 8 + rng.gen_range(max_n - 8);
    let mut entries = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < 0.6 {
                entries.push((i, j, rng.uniform(0.05, 1.0)));
            }
        }
    }
    MetricData::Sparse(SparseDistances {
        n: n as usize,
        entries,
    })
}

fn assert_filtrations_equal(a: &EdgeFiltration, b: &EdgeFiltration, label: &str) {
    assert_eq!(a.n, b.n, "{label}: n");
    assert_eq!(a.edges, b.edges, "{label}: edge order");
    let ab: Vec<u64> = a.values.iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u64> = b.values.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ab, bb, "{label}: value bits");
    assert_eq!(a.tau_max.to_bits(), b.tau_max.to_bits(), "{label}: tau_max");
}

fn assert_neighborhoods_equal(a: &Neighborhoods, b: &Neighborhoods, n: u32, label: &str) {
    assert_eq!(a.is_dense(), b.is_dense(), "{label}");
    assert_eq!(a.memory_bytes(), b.memory_bytes(), "{label}: memory");
    for v in 0..n {
        assert_eq!(a.degree(v), b.degree(v), "{label}: degree({v})");
        assert_eq!(a.vn(v), b.vn(v), "{label}: vn({v})");
        assert_eq!(a.en(v), b.en(v), "{label}: en({v})");
    }
}

/// The satellite's headline property: pooled front-end ==
/// serial front-end, byte for byte, across ≥20 seeds × tile plans ×
/// pool widths × metric input kinds, for both the sparse and the
/// DoryNS neighborhood layout.
#[test]
fn property_pooled_frontend_byte_identical_over_20_seeds() {
    let pools = [ThreadPool::new(2), ThreadPool::new(4)];
    for seed in 0..22u64 {
        let mut rng = Pcg32::new(0xF1F1 + seed);
        let (data, tau) = match seed % 4 {
            0 => (random_cloud(&mut rng, 56, 2), rng.uniform(0.3, 0.7)),
            1 => (random_cloud(&mut rng, 44, 3), rng.uniform(0.5, 1.0)),
            2 => (random_cloud(&mut rng, 40, 3), f64::INFINITY),
            _ => (random_graph(&mut rng, 36), f64::INFINITY),
        };
        let serial = EdgeFiltration::build(&data, tau);
        let nb_serial = Neighborhoods::build(&serial, false);
        let nb_serial_dense = Neighborhoods::build(&serial, true);
        for pool in &pools {
            for tile in [0usize, 1, 3, 17] {
                let label = format!(
                    "seed={seed} threads={} tile={tile} tau={tau}",
                    pool.threads()
                );
                let fe = FrontendOptions {
                    tile,
                    enclosing: false,
                    ..Default::default()
                };
                let mut stats = FiltrationStats::default();
                let pooled =
                    EdgeFiltration::build_pooled(&data, tau, Some(pool), &fe, &mut stats);
                assert_filtrations_equal(&serial, &pooled, &label);
                assert!(stats.tiles > 0, "{label}: distance pass not on the pool");
                if serial.n_edges() > 1 {
                    assert!(stats.sort_chunks > 0, "{label}: sort not on the pool");
                }
                assert_eq!(stats.edges_kept as usize, serial.n_edges(), "{label}");
                assert_eq!(stats.edges_pruned, 0, "{label}: nothing may be pruned");

                let mut nstats = FiltrationStats::default();
                let nb = Neighborhoods::build_pooled(&pooled, false, Some(pool), &mut nstats);
                assert_neighborhoods_equal(&nb_serial, &nb, serial.n, &label);
                if serial.n_edges() > 0 {
                    assert!(nstats.nb_chunks > 0, "{label}: CSR fill not on the pool");
                }
                let nb_d = Neighborhoods::build_pooled(
                    &pooled,
                    true,
                    Some(pool),
                    &mut FiltrationStats::default(),
                );
                assert_neighborhoods_equal(&nb_serial_dense, &nb_d, serial.n, &label);
                for (o, &(a, b)) in serial.edges.iter().enumerate() {
                    assert_eq!(nb.edge_order(a, b), Some(o as u32), "{label}");
                    assert_eq!(nb_d.edge_order(b, a), Some(o as u32), "{label}");
                }
            }
        }
    }
}

/// The PJRT path: an explicit weighted edge list (with heavy value
/// ties) key-sorted on the pool must match the serial sort byte for
/// byte.
#[test]
fn pooled_key_sort_matches_serial_under_ties() {
    let pool = ThreadPool::new(4);
    for seed in 0..20u64 {
        let mut rng = Pcg32::new(0x50FA + seed);
        let n = 40u32;
        let mut raw = Vec::new();
        for a in 0..n {
            for b in (a + 1)..n {
                if rng.next_f64() < 0.7 {
                    // Quantized weights force large tie groups so the
                    // (a, b) tie-break actually decides the order.
                    let d = (rng.gen_range(12) as f64) * 0.125;
                    raw.push((d, a, b));
                }
            }
        }
        let serial = EdgeFiltration::from_weighted_edges(n, raw.clone(), 2.0);
        let mut stats = FiltrationStats::default();
        let pooled = EdgeFiltration::from_weighted_edges_pooled(
            n,
            raw,
            2.0,
            Some(&pool),
            &mut stats,
        );
        assert_filtrations_equal(&serial, &pooled, &format!("seed={seed}"));
        if serial.n_edges() > 1 {
            assert!(stats.sort_chunks > 0, "seed={seed}");
        }
    }
}

/// Enclosing-radius truncation: r_enc matches the brute-force
/// definition, the kept edge set is exactly the serial build at
/// tau = r_enc, and every persistence diagram is bit-identical to the
/// full infinite-tau filtration — across thread counts and tile plans,
/// in both metric input shapes.
#[test]
fn enclosing_radius_preserves_diagrams_bit_for_bit() {
    for seed in 0..6u64 {
        let mut rng = Pcg32::new(0xE2C + seed);
        let data = random_cloud(&mut rng, 36, 3);
        let n = data.n();
        // Brute-force r_enc = min_i max_j d(i, j).
        let pc = match &data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let mut r_enc = f64::INFINITY;
        for i in 0..n {
            let mut m = f64::NEG_INFINITY;
            for j in 0..n {
                if j != i {
                    m = m.max(pc.dist(i, j));
                }
            }
            r_enc = r_enc.min(m);
        }

        let full = EdgeFiltration::build(&data, f64::INFINITY);
        let want = compute_ph_from_filtration(
            &full,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        )
        .diagram;

        for threads in [1usize, 4] {
            for tile in [0usize, 5] {
                let engine = Engine::new(EngineOptions {
                    max_dim: 2,
                    threads,
                    f1_tile: tile,
                    enclosing: true,
                    ..Default::default()
                });
                let r = engine.compute_metric(&data, f64::INFINITY);
                let fs = &r.stats.filtration;
                let label = format!("seed={seed} threads={threads} tile={tile}");
                assert_eq!(
                    fs.enclosing_radius.to_bits(),
                    r_enc.to_bits(),
                    "{label}: r_enc"
                );
                assert_eq!(
                    fs.edges_considered,
                    fs.edges_kept + fs.edges_pruned,
                    "{label}"
                );
                assert!(fs.edges_pruned > 0, "{label}: generic cloud must prune");
                assert_eq!(
                    r.stats.n_edges,
                    EdgeFiltration::build(&data, r_enc).n_edges(),
                    "{label}: kept set == serial build at tau = r_enc"
                );
                assert!(
                    r.diagram.multiset_eq(&want, 0.0),
                    "{label}: truncation changed a diagram"
                );
                // Exact fallback restores the full filtration.
                let off = Engine::new(EngineOptions {
                    max_dim: 2,
                    threads,
                    f1_tile: tile,
                    enclosing: false,
                    ..Default::default()
                })
                .compute_metric(&data, f64::INFINITY);
                assert_eq!(off.stats.n_edges, full.n_edges(), "{label}");
                assert_eq!(off.stats.filtration.edges_pruned, 0, "{label}");
                assert!(off.diagram.multiset_eq(&want, 0.0), "{label}");
            }
        }
    }
}

/// The full engine sweep the acceptance criterion names: diagrams
/// bit-identical across tiles × threads × {enclosing on, off} for
/// finite and infinite thresholds.
#[test]
fn differential_engine_sweep_tiles_threads_enclosing() {
    for seed in 0..4u64 {
        let mut rng = Pcg32::new(0x7E57 + seed);
        let data = random_cloud(&mut rng, 32, 3);
        for tau in [rng.uniform(0.5, 0.9), f64::INFINITY] {
            let want = Engine::new(EngineOptions {
                max_dim: 2,
                threads: 1,
                enclosing: false,
                ..Default::default()
            })
            .compute_metric(&data, tau)
            .diagram;
            for threads in [1usize, 2, 4] {
                for tile in [0usize, 1, 7] {
                    for enclosing in [true, false] {
                        let r = Engine::new(EngineOptions {
                            max_dim: 2,
                            threads,
                            f1_tile: tile,
                            enclosing,
                            ..Default::default()
                        })
                        .compute_metric(&data, tau);
                        assert!(
                            r.diagram.multiset_eq(&want, 0.0),
                            "seed={seed} tau={tau} threads={threads} tile={tile} enclosing={enclosing}"
                        );
                    }
                }
            }
        }
    }
}

/// Pool reuse: the same engine runs front-end + reduction repeatedly;
/// the front-end must keep producing identical bytes on the reused
/// pool (no stale tile state between runs).
#[test]
fn frontend_stable_across_engine_reuse() {
    let mut rng = Pcg32::new(0xAB1E);
    let data = random_cloud(&mut rng, 40, 3);
    let engine = Engine::new(EngineOptions {
        max_dim: 1,
        threads: 4,
        ..Default::default()
    });
    let first = engine.compute_metric(&data, f64::INFINITY);
    // The front-end memory accounting covers every materialized array.
    let f = EdgeFiltration::build(&data, first.stats.filtration.enclosing_radius);
    let nb = Neighborhoods::build(&f, false);
    assert_eq!(
        first.stats.front_memory_bytes,
        f.memory_bytes() + nb.memory_bytes()
    );
    for round in 0..5 {
        let r = engine.compute_metric(&data, f64::INFINITY);
        assert_eq!(r.stats.n_edges, first.stats.n_edges, "round={round}");
        assert_eq!(
            r.stats.filtration.edges_pruned, first.stats.filtration.edges_pruned,
            "round={round}"
        );
        assert!(r.diagram.multiset_eq(&first.diagram, 0.0), "round={round}");
    }
}
