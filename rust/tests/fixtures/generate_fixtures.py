#!/usr/bin/env python3
"""Generate the golden persistence-diagram fixtures for golden_pd.rs.

The fixture files pin the engine's output bit-for-bit:

* the INPUT (point coordinates or sparse distance entries) is stored as
  big-endian IEEE-754 f64 hex bit patterns, so the Rust test reconstructs
  the exact floats regardless of platform or libm;
* the EXPECTED persistence diagram is computed here by an independent
  textbook implementation (flag complex + standard Z/2 boundary-matrix
  reduction over integer bitsets), mirroring rust/src/reduction/
  explicit.rs. Every arithmetic step on the input→PD path (subtraction,
  multiplication, addition in the same order, sqrt, comparisons) is
  IEEE-exact and identical between this script and the Rust engine, so
  the expected values are exact f64 bits, not approximations.

Dataset generation mirrors rust/src/datasets/mod.rs and rust/src/hic/
mod.rs (same PCG32/SplitMix64 streams); transcendentals there may differ
from Rust's libm by an ulp, which is fine — the generated inputs ARE the
fixture, stored exactly.

Run from the repo root:  python3 rust/tests/fixtures/generate_fixtures.py
"""

import math
import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))

M64 = (1 << 64) - 1
M32 = (1 << 32) - 1


def f64_hex(x: float) -> str:
    return struct.pack(">d", float(x)).hex()


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64


class Pcg32:
    """Exact replica of rust/src/util/rng.rs Pcg32 (XSH-RR 64/32)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        init_state = sm.next_u64()
        init_seq = sm.next_u64()
        self.state = 0
        self.inc = ((init_seq << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + init_state) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & M32
        rot = (old >> 59) & 31
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & M32

    def next_u64(self):
        return ((self.next_u32() << 32) | self.next_u32()) & M64

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_range(self, bound):
        # Lemire, matching the Rust implementation exactly.
        x = self.next_u32()
        m = x * bound
        l = m & M32
        if l < bound:
            t = ((1 << 32) - bound) % bound
            while l < t:
                x = self.next_u32()
                m = x * bound
                l = m & M32
        return m >> 32

    def uniform(self, lo, hi):
        return lo + (hi - lo) * self.next_f64()

    def normal(self):
        while True:
            u = self.next_f64()
            v = self.next_f64()
            if u > 1e-12:
                return math.sqrt(-2.0 * math.log(u)) * math.cos(2.0 * math.pi * v)

    def log_normal(self, mu, sigma):
        return math.exp(mu + sigma * self.normal())

    def shuffle(self, xs):
        if not xs:
            return
        for i in range(len(xs) - 1, 0, -1):
            j = self.gen_range(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


# --- dataset generators (mirroring rust/src/datasets & rust/src/hic) ----


def circle(n, radius, noise, seed):
    rng = Pcg32(seed)
    pts = []
    for i in range(n):
        t = 2.0 * math.pi * i / n
        r = radius + noise * rng.normal()
        pts.append((r * math.cos(t), r * math.sin(t)))
    return pts


def torus3(n, big_r, small_r, seed):
    rng = Pcg32(seed)
    pts = []
    for _ in range(n):
        u = 2.0 * math.pi * rng.next_f64()
        v = 2.0 * math.pi * rng.next_f64()
        pts.append(
            (
                (big_r + small_r * math.cos(v)) * math.cos(u),
                (big_r + small_r * math.cos(v)) * math.sin(u),
                small_r * math.sin(v),
            )
        )
    return pts


def hic_generate(n_bins, chroms, window, n_loops, n_domains, tau_max, seed):
    """Control-condition slice of rust/src/hic/mod.rs::generate."""
    rng = Pcg32(seed ^ 0x486943)
    per_chrom = n_bins // chroms
    entries = []
    step = 36.0
    for c in range(chroms):
        lo = c * per_chrom
        hi = n_bins if c == chroms - 1 else (c + 1) * per_chrom
        for i in range(lo, hi):
            for k in range(1, window + 1):
                j = i + k
                if j >= hi:
                    break
                d = step * (float(k) ** 0.6) * (1.0 + 0.08 * rng.normal())
                if 0.0 < d <= tau_max:
                    entries.append((i, j, d))
    loop_rng = Pcg32((seed * 0x9E3779B9) & M64)
    for _li in range(n_loops):
        sep = int(min(max(loop_rng.log_normal(5.2, 0.55), 40.0), 2400.0))
        c = loop_rng.gen_range(chroms)
        lo = c * per_chrom
        hi = n_bins if c == chroms - 1 else (c + 1) * per_chrom
        if hi - lo <= sep + 2:
            continue
        i = lo + loop_rng.gen_range(hi - lo - sep)
        j = i + sep
        anchor_d = 20.0 + 330.0 * loop_rng.next_f64()
        stem = 4 + loop_rng.gen_range(6)
        for k in range(stem + 1):
            if i >= lo + k and j + k < hi:
                d = anchor_d + 14.0 * k * (1.0 + 0.05 * loop_rng.normal())
                if d <= tau_max:
                    entries.append((i - k, j + k, max(d, 1.0)))
    dom_rng = Pcg32((seed * 0x2545F491) & M64)
    phi = math.pi * (3.0 - math.sqrt(5.0))
    for _di in range(n_domains):
        span = 60 + dom_rng.gen_range(60)
        c = dom_rng.gen_range(chroms)
        lo = c * per_chrom
        hi = n_bins if c == chroms - 1 else (c + 1) * per_chrom
        if hi - lo <= span + 2:
            continue
        start = lo + dom_rng.gen_range(hi - lo - span)
        radius = 70.0 + 90.0 * dom_rng.next_f64()
        pos = []
        for s in range(span):
            y = 1.0 - 2.0 * (s + 0.5) / span
            r = math.sqrt(1.0 - y * y)
            t = phi * s
            pos.append((radius * r * math.cos(t), radius * y, radius * r * math.sin(t)))
        order = list(range(span))
        dom_rng.shuffle(order)
        for a in range(span):
            for b in range(a + 1, span):
                p, q = pos[order[a]], pos[order[b]]
                d = max(
                    math.sqrt(
                        (p[0] - q[0]) ** 2 + (p[1] - q[1]) ** 2 + (p[2] - q[2]) ** 2
                    ),
                    1.0,
                )
                if d <= tau_max:
                    entries.append((start + a, start + b, d))
    # Deduplicate, keeping the smallest distance per (u, v).
    entries.sort(key=lambda e: (e[0], e[1], e[2]))
    dedup = []
    last = None
    for e in entries:
        if last is not None and e[0] == last[0] and e[1] == last[1]:
            continue
        dedup.append(e)
        last = e
    return dedup


# --- edge filtration + flag-complex oracle ------------------------------


def point_dist(p, q):
    """Exactly EdgeFiltration::build's loop: s += d*d in coordinate order."""
    s = 0.0
    for a, b in zip(p, q):
        d = a - b
        s += d * d
    return math.sqrt(s)


def edges_from_points(points, tau):
    raw = []
    for i in range(len(points)):
        for j in range(i + 1, len(points)):
            d = point_dist(points[i], points[j])
            if d <= tau:
                raw.append((d, i, j))
    raw.sort(key=lambda e: (e[0], e[1], e[2]))
    return raw


def edges_from_sparse(entries, tau):
    raw = [(d, u, v) for (u, v, d) in entries if d <= tau]
    raw.sort(key=lambda e: (e[0], e[1], e[2]))
    return raw


def oracle_diagram(n_vertices, edges, max_dim):
    """Standard Z/2 column reduction on the flag complex up to
    max_dim + 1, mirroring rust/src/reduction/explicit.rs. Returns a
    dict dim -> (finite [(birth, death)], essential [birth])."""
    order = {}
    adj = [dict() for _ in range(n_vertices)]
    values = []
    for o, (d, a, b) in enumerate(edges):
        order[(a, b)] = o
        adj[a][b] = o
        adj[b][a] = o
        values.append(d)

    # Simplices as (value, dim, verts).
    simplices = [(0.0, 0, (v,)) for v in range(n_vertices)]
    for (d, a, b) in edges:
        simplices.append((d, 1, (a, b)))
    top_dim = max_dim + 1
    if top_dim >= 2:
        for a in range(n_vertices):
            for b in range(a + 1, n_vertices):
                oab = adj[a].get(b)
                if oab is None:
                    continue
                for c in range(b + 1, n_vertices):
                    oac = adj[a].get(c)
                    obc = adj[b].get(c)
                    if oac is None or obc is None:
                        continue
                    diam = max(oab, oac, obc)
                    simplices.append((values[diam], 2, (a, b, c)))
                    if top_dim >= 3:
                        for e in range(c + 1, n_vertices):
                            oae = adj[a].get(e)
                            obe = adj[b].get(e)
                            oce = adj[c].get(e)
                            if oae is None or obe is None or oce is None:
                                continue
                            diam3 = max(diam, oae, obe, oce)
                            simplices.append((values[diam3], 3, (a, b, c, e)))
    simplices.sort(key=lambda s: (s[0], s[1], s[2]))
    index = {s[2]: i for i, s in enumerate(simplices)}
    n = len(simplices)

    # Sparse boundary columns as integer bitsets.
    cols = []
    for (_, dim, verts) in simplices:
        col = 0
        if dim > 0:
            for omit in range(len(verts)):
                face = verts[:omit] + verts[omit + 1 :]
                col ^= 1 << index[face]
        cols.append(col)

    NONE = -1
    low = [NONE] * n
    pivot_of_row = {}
    for j in range(n):
        col = cols[j]
        while col:
            l = col.bit_length() - 1
            i = pivot_of_row.get(l)
            if i is None:
                low[j] = l
                pivot_of_row[l] = j
                break
            col ^= cols[i]
        cols[j] = col
        if not col:
            low[j] = NONE

    out = {d: ([], []) for d in range(max_dim + 1)}
    is_pivot_row = [False] * n
    for j in range(n):
        if low[j] != NONE:
            is_pivot_row[low[j]] = True
    for j in range(n):
        if low[j] != NONE:
            i = low[j]
            d = simplices[i][1]
            if d <= max_dim:
                birth, death = simplices[i][0], simplices[j][0]
                if birth != death:
                    out[d][0].append((birth, death))
        elif not is_pivot_row[j]:
            d = simplices[j][1]
            if d <= max_dim:
                out[d][1].append(simplices[j][0])
    return out


def betti_at(diagram, dim, t):
    fin, ess = diagram[dim]
    alive = sum(1 for (b, d) in fin if b <= t < d)
    return alive + sum(1 for b in ess if b <= t)


# --- feature products (mirroring rust/src/features/) --------------------
#
# Each kernel below replays the Rust implementation's float operations in
# the same order on the same f64 values (Python floats ARE IEEE f64), so
# the expected feature values differ from the engine's by at most a libm
# ulp in exp/log — the Rust test compares at 1e-12 relative tolerance,
# and the integer Betti curves exactly.


def clamped_sorted(diagram, dim, span):
    """features::clamped_sorted — deaths (incl. ∞ essentials) clamped to
    span, canonical (birth, death) sort."""
    fin, ess = diagram[dim]
    pts = []
    clamped = 0
    for (b, d) in fin:
        if d > span:
            clamped += 1
            pts.append((b, span))
        else:
            pts.append((b, d))
    for b in ess:
        clamped += 1
        pts.append((b, span))
    pts.sort()  # finite positive floats: tuple sort == total_cmp order
    return pts, clamped


def betti_curve(diagram, dim, grid, span):
    return [betti_at(diagram, dim, span * i / grid) for i in range(grid + 1)]


def pers_entropy(points):
    total = 0.0
    for (b, d) in points:
        total += d - b
    if not total > 0.0:
        return 0.0
    e = 0.0
    for (b, d) in points:
        p = (d - b) / total
        if p > 0.0:
            e -= p * math.log(p)
    return e


def pers_landscape(points, levels, grid, span):
    out = [[0.0] * (grid + 1) for _ in range(levels)]
    for i in range(grid + 1):
        t = span * i / grid
        tents = []
        for (b, d) in points:
            v = min(t - b, d - t)
            if v > 0.0:
                tents.append(v)
        tents.sort(reverse=True)
        for k in range(levels):
            out[k][i] = tents[k] if k < len(tents) else 0.0
    return out


def pers_image(points, grid, span):
    """features::image::serial — SIGMA_FRAC 0.05, 1e-30 regularizer,
    half-cell centers, persistence-weighted, row-major [row*grid+col]."""
    sigma = 0.05 * span
    inv2s2 = 1.0 / (2.0 * sigma * sigma + 1e-30)
    cell = span / grid
    out = [0.0] * (grid * grid)
    for r in range(grid):
        y = (r + 0.5) * cell
        for c in range(grid):
            x = (c + 0.5) * cell
            acc = 0.0
            for (b, d) in points:
                pers = d - b
                dx = x - b
                dy = y - pers
                acc += pers * math.exp(-(dx * dx + dy * dy) * inv2s2)
            out[r * grid + c] = acc
    return out


FEATURE_BETTI_GRID = 16
FEATURE_LANDSCAPE_LEVELS = 3
FEATURE_LANDSCAPE_GRID = 16
FEATURE_IMAGE_GRID = 16


def write_feature_fixture(path, name, span, max_dim, diagram):
    lines = [
        "# dory golden feature-product fixture",
        "# generated by rust/tests/fixtures/generate_fixtures.py",
        "# f64 values are big-endian IEEE-754 bit patterns in hex",
        f"name {name}",
        f"span {f64_hex(span)}",
        f"max_dim {max_dim}",
        f"betti_grid {FEATURE_BETTI_GRID}",
        f"landscape_levels {FEATURE_LANDSCAPE_LEVELS}",
        f"landscape_grid {FEATURE_LANDSCAPE_GRID}",
        f"image_grid {FEATURE_IMAGE_GRID}",
    ]
    for dim in range(max_dim + 1):
        pts, clamped = clamped_sorted(diagram, dim, span)
        lines.append(f"clamped {dim} {clamped}")
        bc = betti_curve(diagram, dim, FEATURE_BETTI_GRID, span)
        lines.append(f"betti {dim} " + " ".join(str(v) for v in bc))
        lines.append(f"entropy {dim} {f64_hex(pers_entropy(pts))}")
        ls = pers_landscape(
            pts, FEATURE_LANDSCAPE_LEVELS, FEATURE_LANDSCAPE_GRID, span
        )
        for k, level in enumerate(ls):
            lines.append(
                f"landscape {dim} {k} " + " ".join(f64_hex(v) for v in level)
            )
        img = pers_image(pts, FEATURE_IMAGE_GRID, span)
        for r in range(FEATURE_IMAGE_GRID):
            row = img[r * FEATURE_IMAGE_GRID : (r + 1) * FEATURE_IMAGE_GRID]
            lines.append(f"image {dim} {r} " + " ".join(f64_hex(v) for v in row))
    lines.append("end")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path}")


# --- fixture writing ----------------------------------------------------


def write_fixture(path, name, kind, max_dim, tau, payload, diagram):
    lines = [
        "# dory golden persistence-diagram fixture",
        "# generated by rust/tests/fixtures/generate_fixtures.py",
        "# f64 values are big-endian IEEE-754 bit patterns in hex",
        f"name {name}",
        f"kind {kind}",
        f"max_dim {max_dim}",
        f"tau {f64_hex(tau)}",
    ]
    if kind == "points":
        points = payload
        lines.append(f"dim {len(points[0])}")
        lines.append(f"n {len(points)}")
        for p in points:
            lines.append("point " + " ".join(f64_hex(c) for c in p))
    else:
        n, entries = payload
        lines.append(f"n {n}")
        for (u, v, d) in entries:
            lines.append(f"entry {u} {v} {f64_hex(d)}")
    total = 0
    for d in range(max_dim + 1):
        fin, ess = diagram[d]
        for (b, dd) in sorted(fin):
            lines.append(f"pd {d} {f64_hex(b)} {f64_hex(dd)}")
            total += 1
        for b in sorted(ess):
            lines.append(f"pd {d} {f64_hex(b)} inf")
            total += 1
    lines.append("end")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path}: {total} PD points")


def main():
    # --- circle: one loop, H0+H1 ------------------------------------
    pts = circle(48, 1.0, 0.05, 1)
    tau = 3.0
    edges = edges_from_points(pts, tau)
    dg = oracle_diagram(len(pts), edges, 1)
    print(f"circle48: {len(edges)} edges, H0 ess {len(dg[0][1])}, "
          f"H1 fin {len(dg[1][0])} ess {len(dg[1][1])}")
    assert len(dg[0][1]) == 1, "circle must be connected at tau=3"
    long_loops = [p for p in dg[1][0] if p[1] - p[0] > 0.5] + dg[1][1]
    assert len(long_loops) == 1, f"circle must carry one dominant loop: {long_loops}"
    write_fixture(
        os.path.join(HERE, "circle48.pd.txt"), "circle48", "points", 1, tau, pts, dg
    )
    write_feature_fixture(
        os.path.join(HERE, "circle48.features.txt"), "circle48", tau, 1, dg
    )

    # --- torus: H0+H1+H2 --------------------------------------------
    n_torus = 110
    pts = torus3(n_torus, 2.0, 0.7, 2)
    tau = 1.6
    edges = edges_from_points(pts, tau)
    dg = oracle_diagram(len(pts), edges, 2)
    print(f"torus{n_torus}: {len(edges)} edges, H0 ess {len(dg[0][1])}, "
          f"H1 fin {len(dg[1][0])} ess {len(dg[1][1])}, "
          f"H2 fin {len(dg[2][0])} ess {len(dg[2][1])}")
    assert len(dg[0][1]) == 1, "torus sample must be connected"
    write_fixture(
        os.path.join(HERE, f"torus{n_torus}.pd.txt"),
        f"torus{n_torus}",
        "points",
        2,
        tau,
        pts,
        dg,
    )

    # --- Hi-C slice: sparse non-metric input, H0+H1 ------------------
    n_bins = 240
    tau = 150.0
    entries = hic_generate(n_bins, 2, 8, 15, 2, tau, 2021)
    edges = edges_from_sparse(entries, tau)
    dg = oracle_diagram(n_bins, edges, 1)
    print(f"hic240: {len(entries)} entries, {len(edges)} edges, "
          f"H0 ess {len(dg[0][1])}, H1 fin {len(dg[1][0])} ess {len(dg[1][1])}")
    assert len(dg[0][1]) >= 2, "two chromosomes stay disconnected"
    write_fixture(
        os.path.join(HERE, "hic240.pd.txt"),
        "hic240",
        "sparse",
        1,
        tau,
        (n_bins, entries),
        dg,
    )
    write_feature_fixture(
        os.path.join(HERE, "hic240.features.txt"), "hic240", tau, 1, dg
    )


if __name__ == "__main__":
    main()
