//! Golden persistence-diagram regression fixtures.
//!
//! Each fixture in `rust/tests/fixtures/*.pd.txt` stores an input
//! (point coordinates or sparse distance entries) *and* its expected
//! persistence diagram, both as exact IEEE-754 f64 bit patterns. The
//! engine must reproduce the diagram **bit for bit** — across the
//! sequential path and several pipelined work-stealing configurations —
//! which pins down both the numerics (the input→PD path uses only
//! IEEE-exact operations: ±, ×, `sqrt`, comparisons) and the scheduler's
//! exactness guarantee on real known-topology datasets.
//!
//! The expected diagrams were produced by an independent textbook
//! implementation (`fixtures/generate_fixtures.py`, cross-checked
//! against a second reduction algorithm). To regenerate after an
//! *intentional* semantic change, run with `DORY_REGEN_GOLDEN=1` — the
//! fixtures are then rewritten from the in-tree explicit oracle — and
//! commit the diff.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use dory::filtration::{EdgeFiltration, Neighborhoods};
use dory::geometry::{MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph, EngineOptions};
use dory::reduction::explicit::oracle_diagram;

struct Fixture {
    name: String,
    max_dim: usize,
    tau: f64,
    data: MetricData,
    /// (dim, birth bits, death bits); essential deaths are +inf bits.
    pd: Vec<(usize, u64, u64)>,
    path: PathBuf,
}

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

fn parse_hex_f64(s: &str) -> f64 {
    f64::from_bits(u64::from_str_radix(s, 16).unwrap_or_else(|e| panic!("bad hex {s}: {e}")))
}

fn load_fixture(path: &Path) -> Fixture {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
    let mut name = String::new();
    let mut kind = String::new();
    let mut max_dim = 0usize;
    let mut tau = f64::INFINITY;
    let mut dim = 0usize;
    let mut n = 0usize;
    let mut coords: Vec<f64> = Vec::new();
    let mut entries: Vec<(u32, u32, f64)> = Vec::new();
    let mut pd: Vec<(usize, u64, u64)> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line == "end" {
            continue;
        }
        let mut it = line.split_whitespace();
        let tag = it.next().unwrap();
        match tag {
            "name" => name = it.next().unwrap().to_string(),
            "kind" => kind = it.next().unwrap().to_string(),
            "max_dim" => max_dim = it.next().unwrap().parse().unwrap(),
            "tau" => tau = parse_hex_f64(it.next().unwrap()),
            "dim" => dim = it.next().unwrap().parse().unwrap(),
            "n" => n = it.next().unwrap().parse().unwrap(),
            "point" => coords.extend(it.map(parse_hex_f64)),
            "entry" => {
                let u: u32 = it.next().unwrap().parse().unwrap();
                let v: u32 = it.next().unwrap().parse().unwrap();
                let d = parse_hex_f64(it.next().unwrap());
                entries.push((u, v, d));
            }
            "pd" => {
                let d: usize = it.next().unwrap().parse().unwrap();
                let birth = parse_hex_f64(it.next().unwrap()).to_bits();
                let death_tok = it.next().unwrap();
                let death = if death_tok == "inf" {
                    f64::INFINITY.to_bits()
                } else {
                    parse_hex_f64(death_tok).to_bits()
                };
                pd.push((d, birth, death));
            }
            other => panic!("{path:?}: unknown tag {other}"),
        }
    }
    let data = match kind.as_str() {
        "points" => {
            assert_eq!(coords.len(), n * dim, "{path:?}: point count");
            MetricData::Points(PointCloud::new(dim, coords))
        }
        "sparse" => MetricData::Sparse(SparseDistances { n, entries }),
        other => panic!("{path:?}: unknown kind {other}"),
    };
    pd.sort_unstable();
    Fixture {
        name,
        max_dim,
        tau,
        data,
        pd,
        path: path.to_path_buf(),
    }
}

fn diagram_bits(d: &dory::homology::Diagram, max_dim: usize) -> Vec<(usize, u64, u64)> {
    let mut out = Vec::new();
    for dim in 0..=max_dim {
        for p in d.points(dim) {
            out.push((dim, p.birth.to_bits(), p.death.to_bits()));
        }
    }
    out.sort_unstable();
    out
}

fn describe(pd: &[(usize, u64, u64)], max_dim: usize) -> String {
    let mut s = String::new();
    for dim in 0..=max_dim {
        let _ = write!(s, "dim{dim}: {}  ", pd.iter().filter(|p| p.0 == dim).count());
    }
    s
}

/// Rewrite a fixture's `pd` lines from the in-tree explicit oracle.
fn regen(fx: &Fixture) {
    let f = EdgeFiltration::build(&fx.data, fx.tau);
    let nb = Neighborhoods::build(&f, false);
    let want = oracle_diagram(&f, &nb, fx.max_dim);
    let bits = diagram_bits(&want, fx.max_dim);
    let text = std::fs::read_to_string(&fx.path).unwrap();
    let mut out = String::new();
    for line in text.lines() {
        if line.starts_with("pd ") || line == "end" {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    for &(dim, b, d) in &bits {
        if d == f64::INFINITY.to_bits() {
            let _ = writeln!(out, "pd {dim} {:016x} inf", b);
        } else {
            let _ = writeln!(out, "pd {dim} {:016x} {:016x}", b, d);
        }
    }
    out.push_str("end\n");
    std::fs::write(&fx.path, out).unwrap();
    eprintln!("regenerated {:?} ({} points)", fx.path, bits.len());
}

fn check_fixture(file: &str) {
    let path = fixtures_dir().join(file);
    let fx = load_fixture(&path);
    if std::env::var_os("DORY_REGEN_GOLDEN").is_some() {
        regen(&fx);
        return;
    }
    // Sequential and pipelined configurations must all hit the golden
    // bits exactly.
    let configs: Vec<(&str, EngineOptions)> = vec![
        (
            "sequential",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 1,
                ..Default::default()
            },
        ),
        (
            "t4-adaptive",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                batch_size: 32,
                adaptive_batch: true,
                batch_min: 4,
                batch_max: 256,
                ..Default::default()
            },
        ),
        (
            "t2-batch7",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 2,
                batch_size: 7,
                adaptive_batch: false,
                ..Default::default()
            },
        ),
        (
            "t8-grain1",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 8,
                batch_size: 100,
                adaptive_batch: false,
                steal_grain: 1,
                ..Default::default()
            },
        ),
        // Sharded column enumeration at several shard geometries: the
        // spliced stream must leave the golden bits untouched.
        (
            "t4-shards1",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                enum_shards: 1,
                ..Default::default()
            },
        ),
        (
            "t4-shards3",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                batch_size: 17,
                adaptive_batch: false,
                enum_shards: 3,
                ..Default::default()
            },
        ),
        (
            "t8-shards13",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 8,
                batch_size: 32,
                adaptive_batch: false,
                enum_shards: 13,
                steal_grain: 1,
                ..Default::default()
            },
        ),
        (
            "t2-grain5",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 2,
                batch_size: 7,
                adaptive_batch: false,
                enum_grain: 5,
                ..Default::default()
            },
        ),
        // The apparent-pair shortcut is on in every configuration above
        // (the default); the exact fallback must hit the same bits.
        (
            "seq-noshortcut",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 1,
                shortcut: false,
                ..Default::default()
            },
        ),
        (
            "t4-noshortcut",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                batch_size: 17,
                adaptive_batch: false,
                enum_shards: 3,
                shortcut: false,
                ..Default::default()
            },
        ),
        // The pooled front-end builds the filtration for every threaded
        // config above (enclosing is on by default); these two pin the
        // enclosing knob in both positions, with a non-auto tile plan,
        // against the same golden bits.
        (
            "t4-enclosing-tile7",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                f1_tile: 7,
                enclosing: true,
                ..Default::default()
            },
        ),
        (
            "t4-noenclosing",
            EngineOptions {
                max_dim: fx.max_dim,
                threads: 4,
                batch_size: 17,
                adaptive_batch: false,
                f1_tile: 3,
                enclosing: false,
                ..Default::default()
            },
        ),
    ];
    // The fixtures carry finite taus, where the enclosing truncation is
    // inert by design — so the knob is additionally pinned at τ = +∞ on
    // the metric (points) fixtures: with and without the truncation,
    // serial and pooled, the diagrams must agree to the bit (the VR
    // complex is a cone beyond r_enc).
    if matches!(fx.data, MetricData::Points(_)) {
        // Capped at H1: the τ = +∞ flag complex on the larger fixtures
        // is too big for debug-mode H2 (dim-2 enclosing coverage lives
        // in rust/tests/frontend.rs on small clouds).
        let mk = |threads: usize, enclosing: bool| EngineOptions {
            max_dim: fx.max_dim.min(1),
            threads,
            enclosing,
            ..Default::default()
        };
        let reference = compute_ph(&fx.data, f64::INFINITY, &mk(1, false));
        for (label, opts) in [
            ("inf-seq-enclosing", mk(1, true)),
            ("inf-t4-enclosing", mk(4, true)),
            ("inf-t4-noenclosing", mk(4, false)),
        ] {
            let r = compute_ph(&fx.data, f64::INFINITY, &opts);
            let got = diagram_bits(&r.diagram, fx.max_dim);
            let want = diagram_bits(&reference.diagram, fx.max_dim);
            assert_eq!(
                got, want,
                "{} [{}]: enclosing truncation changed the diagram at tau = inf",
                fx.name, label
            );
            if opts.enclosing {
                assert!(
                    r.stats.filtration.edges_pruned > 0,
                    "{} [{}]: truncation never fired",
                    fx.name,
                    label
                );
            }
        }
    }

    for (label, opts) in configs {
        let r = compute_ph(&fx.data, fx.tau, &opts);
        let got = diagram_bits(&r.diagram, fx.max_dim);
        if got != fx.pd {
            let first_diff = got
                .iter()
                .zip(&fx.pd)
                .position(|(a, b)| a != b)
                .unwrap_or(got.len().min(fx.pd.len()));
            panic!(
                "{} [{}]: diagram deviates from golden fixture\n got: {}\nwant: {}\nfirst difference at sorted index {} (got {:?} vs want {:?})",
                fx.name,
                label,
                describe(&got, fx.max_dim),
                describe(&fx.pd, fx.max_dim),
                first_diff,
                got.get(first_diff),
                fx.pd.get(first_diff),
            );
        }
    }
}

#[test]
fn golden_circle48() {
    check_fixture("circle48.pd.txt");
}

#[test]
fn golden_torus110() {
    check_fixture("torus110.pd.txt");
}

#[test]
fn golden_hic240() {
    check_fixture("hic240.pd.txt");
}
