//! Cross-module integration tests: coordinator pipeline, engine-vs-
//! baseline agreement on real datasets, input-format equivalence, and
//! failure injection.

use dory::baselines::{gudhi_like, ripser_like};
use dory::coordinator::{self, DatasetSpec, RunConfig};
use dory::datasets;
use dory::filtration::EdgeFiltration;
use dory::geometry::{DenseDistances, MetricData, PointCloud, SparseDistances};
use dory::homology::{compute_ph, compute_ph_from_filtration, EngineOptions};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("dory-itest").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn three_engines_agree_on_benchmark_datasets() {
    // Medium-size instances of each benchmark family, all three code
    // paths (Dory implicit / ripser-like heap / gudhi-like explicit).
    let cases: Vec<(&str, MetricData, f64, usize)> = vec![
        ("dragon", datasets::dragon_like(150, 1), 1.2, 1),
        ("fractal", datasets::fractal_network(2), f64::INFINITY, 2),
        ("o3", datasets::o3(60, 2), 1.4, 2),
        ("torus4", datasets::torus4(120, 3), 0.8, 2),
    ];
    for (name, data, tau, dim) in cases {
        let opts = EngineOptions {
            max_dim: dim,
            threads: 2,
            ..Default::default()
        };
        let dory = compute_ph(&data, tau, &opts).diagram;
        let rip = ripser_like::compute_ph(&data, tau, dim, usize::MAX).unwrap();
        let gud = gudhi_like::compute_ph(&data, tau, dim);
        assert!(
            dory.multiset_eq(&rip, 2e-4),
            "{name}: dory vs ripser-like\n{}",
            dory.diff_summary(&rip)
        );
        assert!(
            dory.multiset_eq(&gud, 1e-9),
            "{name}: dory vs gudhi-like\n{}",
            dory.diff_summary(&gud)
        );
    }
}

#[test]
fn input_formats_are_equivalent() {
    // The same metric delivered as points, dense matrix, and sparse list
    // must give identical diagrams.
    let data = datasets::circle(60, 1.0, 0.05, 9);
    let pc = match &data {
        MetricData::Points(p) => p.clone(),
        _ => unreachable!(),
    };
    let tau = 1.5;
    let dense = MetricData::Dense(DenseDistances::from_points(&pc));
    let mut entries = Vec::new();
    for i in 0..pc.n() as u32 {
        for j in (i + 1)..pc.n() as u32 {
            let d = pc.dist(i as usize, j as usize);
            if d <= tau {
                entries.push((i, j, d));
            }
        }
    }
    let sparse = MetricData::Sparse(SparseDistances {
        n: pc.n(),
        entries,
    });
    let opts = EngineOptions::default();
    let a = compute_ph(&data, tau, &opts).diagram;
    let b = compute_ph(&dense, tau, &opts).diagram;
    let c = compute_ph(&sparse, tau, &opts).diagram;
    assert!(a.multiset_eq(&b, 1e-12));
    assert!(a.multiset_eq(&c, 1e-12));
}

#[test]
fn pair_count_decomposition_invariant() {
    // Every edge is either an H0 death or an H1 birth; every H1 birth is
    // a (possibly trivial) pair or essential. Same one dimension up.
    for seed in 0..4 {
        let data = datasets::random_cloud(40, 3, seed);
        let f = EdgeFiltration::build(&data, 0.7);
        let r = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                ..Default::default()
            },
        );
        let ne = f.n_edges();
        let s = &r.stats;
        assert_eq!(
            s.h0_deaths + s.h1_cleared.max(s.h0_deaths) - s.h0_deaths, // h1_cleared == h0_deaths
            s.h1_cleared
        );
        assert_eq!(
            ne,
            s.h0_deaths + s.h1.pairs + s.h1.trivial_pairs + s.h1.essential,
            "edge decomposition (seed={seed})"
        );
        // Triangle columns: streamed + shortcut-skipped (apparent pairs
        // resolved at enumeration, counted in h2.trivial_pairs) +
        // cleared (H1 deaths) = H2 pairs + trivial + essential.
        let triangles = s.h2.columns + s.h2.shortcut_pairs + s.h2_cleared;
        assert_eq!(
            triangles,
            s.h1.pairs + s.h1.trivial_pairs + s.h2.pairs + s.h2.trivial_pairs + s.h2.essential,
            "triangle decomposition (seed={seed})"
        );
    }
}

#[test]
fn coordinator_config_roundtrip_outputs() {
    let dir = tmpdir("roundtrip");
    let cfg_text = format!(
        r#"
[dataset]
kind = "figure-eight"
n = 120
seed = 5

[engine]
tau = 1.5
max_dim = 1
threads = 2

[runtime]
use_pjrt = false

[output]
diagram_csv = "{0}/pd.csv"
diagram_json = "{0}/pd.json"
summary_json = "{0}/summary.json"
"#,
        dir.display()
    );
    let cfg = RunConfig::from_str(&cfg_text).unwrap();
    let report = coordinator::run(&cfg).unwrap();
    assert_eq!(report.result.diagram.essential_count(0), 1);
    // Both loops of the figure-eight live long.
    assert_eq!(report.result.diagram.significant(1, 0.5).len(), 2);
    for f in ["pd.csv", "pd.json", "summary.json"] {
        assert!(dir.join(f).is_file(), "{f} missing");
    }
    let pd = std::fs::read_to_string(dir.join("pd.csv")).unwrap();
    assert!(pd.starts_with("dim,birth,death"));
    let sj = std::fs::read_to_string(dir.join("summary.json")).unwrap();
    assert!(sj.contains("\"edge_source\":\"native\""), "{sj}");
}

#[test]
fn coordinator_reads_files_back() {
    // generate -> write -> read -> identical PH.
    let dir = tmpdir("files");
    let data = datasets::circle(50, 1.0, 0.02, 4);
    let pc = match &data {
        MetricData::Points(p) => p.clone(),
        _ => unreachable!(),
    };
    let path = dir.join("pts.xyz");
    dory::io::write_points(&path, &pc).unwrap();
    let cfg = RunConfig {
        dataset: DatasetSpec::PointsFile(path),
        tau: 3.0,
        max_dim: 1,
        use_pjrt: false,
        ..Default::default()
    };
    let r = coordinator::run(&cfg).unwrap();
    let direct = compute_ph(
        &data,
        3.0,
        &EngineOptions {
            max_dim: 1,
            ..Default::default()
        },
    );
    assert!(r.result.diagram.multiset_eq(&direct.diagram, 1e-12));
}

#[test]
fn failure_injection() {
    // Unknown dataset kind.
    assert!(coordinator::build_dataset(&DatasetSpec::Named {
        kind: "no-such".into(),
        n: 10,
        seed: 1
    })
    .is_err());
    // Missing file.
    assert!(coordinator::build_dataset(&DatasetSpec::PointsFile(
        "/definitely/not/here.xyz".into()
    ))
    .is_err());
    // Invalid configs.
    assert!(RunConfig::from_str("[engine]\nmax_dim = 9\n").is_err());
    assert!(RunConfig::from_str("[engine]\ntau = \"high\"\n").is_err());
    // Bad hic condition surfaces at build time.
    assert!(coordinator::build_dataset(&DatasetSpec::Hic {
        n_bins: 100,
        condition: "mock".into(),
        seed: 1
    })
    .is_err());
}

#[test]
fn empty_and_degenerate_inputs() {
    // One point: a single essential component, nothing else.
    let one = MetricData::Points(PointCloud::new(2, vec![0.0, 0.0]));
    let r = compute_ph(&one, 1.0, &EngineOptions::default());
    assert_eq!(r.diagram.essential_count(0), 1);
    assert_eq!(r.diagram.finite(0).len(), 0);
    assert!(r.diagram.points(1).is_empty());

    // tau smaller than every distance: n isolated components.
    let spread = MetricData::Points(PointCloud::new(1, vec![0.0, 10.0, 20.0]));
    let r = compute_ph(&spread, 1.0, &EngineOptions::default());
    assert_eq!(r.diagram.essential_count(0), 3);

    // Duplicate points (zero-length edges).
    let dup = MetricData::Points(PointCloud::new(2, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0]));
    let r = compute_ph(&dup, 5.0, &EngineOptions::default());
    assert_eq!(r.diagram.essential_count(0), 1);
}

#[test]
fn hic_conditions_share_backbone() {
    // Auxin removes loops/domains but the chain itself is untouched: H0
    // structure (chromosome count) must match between conditions.
    use dory::hic::{self, Condition, HiCParams};
    let p = HiCParams {
        n_bins: 3000,
        chroms: 5,
        ..Default::default()
    };
    let opts = EngineOptions {
        max_dim: 0,
        ..Default::default()
    };
    let c = compute_ph(
        &MetricData::Sparse(hic::generate(&p, Condition::Control)),
        p.tau_max,
        &opts,
    );
    let a = compute_ph(
        &MetricData::Sparse(hic::generate(&p, Condition::Auxin)),
        p.tau_max,
        &opts,
    );
    assert_eq!(c.diagram.essential_count(0), 5, "five chromosomes");
    assert_eq!(a.diagram.essential_count(0), 5);
}
