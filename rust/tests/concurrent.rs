//! Concurrent-serving exactness harness.
//!
//! The multi-tenant contract under test:
//!
//! * **Concurrent ≡ serial, bit for bit** — M threads querying one
//!   session through `&self` (same handle or distinct handles, shortcut
//!   on or off, batches or single queries) produce diagrams whose
//!   (dim, birth-bits, death-bits) sequences equal a serial baseline at
//!   tolerance zero, for every interleaving the scheduler happens to
//!   pick;
//! * **fair shared pool** — all of it on ONE work-stealing pool whose
//!   multi-generation scheduler interleaves the queries' task
//!   generations; nothing is rebuilt (`filtration_builds` stays at the
//!   ingest count);
//! * **wire front under contention** — concurrent `Server::handle_line`
//!   calls (mixed tenants, cache hits, malformed requests) keep every
//!   response well-formed and every typed error intact.

use dory::error::DoryError;
use dory::geometry::{MetricData, PointCloud};
use dory::homology::{compute_ph, EngineOptions, PhRequest, PhResponse, Session};
use dory::serve::Server;
use dory::util::json::Json;
use dory::util::rng::Pcg32;

fn cloud(n: usize, dim: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

fn diagram_bits(d: &dory::homology::Diagram) -> Vec<(usize, u64, u64)> {
    let mut out = Vec::new();
    for dim in 0..=d.max_dim() {
        for p in d.points(dim) {
            out.push((dim, p.birth.to_bits(), p.death.to_bits()));
        }
    }
    out
}

fn response_bits(r: &PhResponse) -> Vec<(usize, u64, u64)> {
    diagram_bits(&r.result.diagram)
}

/// 8 threads hammer ONE handle of one session concurrently, each at its
/// own τ, swept over shortcut on/off. Every response must be
/// bit-identical to the serial baseline computed beforehand.
#[test]
fn concurrent_queries_on_one_handle_match_serial_baseline() {
    let data = cloud(28, 3, 9001);
    let taus = [0.25, 0.35, 0.45, 0.55, 0.65, 0.75, 0.85, 0.95];
    for shortcut in [true, false] {
        let opts = EngineOptions {
            max_dim: 2,
            threads: 4,
            shortcut,
            ..Default::default()
        };
        let session = Session::new(opts.clone());
        let handle = session.ingest(&data, 0.95).unwrap();
        // Serial baseline first, on the same session (prefix queries are
        // already pinned bit-identical to fresh runs by tests/session.rs).
        let serial: Vec<_> = taus
            .iter()
            .map(|&t| response_bits(&session.query(&handle, &PhRequest::at(t)).unwrap()))
            .collect();
        let queries_before = session.stats().queries;
        for round in 0..3 {
            let concurrent: Vec<_> = std::thread::scope(|scope| {
                let handles: Vec<_> = taus
                    .iter()
                    .map(|&t| {
                        let session = &session;
                        let handle = &handle;
                        scope.spawn(move || {
                            response_bits(&session.query(handle, &PhRequest::at(t)).unwrap())
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (i, (c, s)) in concurrent.iter().zip(&serial).enumerate() {
                assert_eq!(
                    c, s,
                    "shortcut={shortcut} round={round} tau={}: concurrent diagram deviates",
                    taus[i]
                );
            }
        }
        let st = session.stats();
        assert_eq!(st.queries - queries_before, 3 * taus.len() as u64);
        // One ingest, one build — concurrency rebuilt nothing.
        assert_eq!(st.filtration_builds, 1);
        assert_eq!(st.nb_builds, 1);
    }
}

/// Distinct handles (different datasets) queried concurrently on one
/// session: per-handle results must match each handle's serial run.
#[test]
fn concurrent_queries_on_distinct_handles_match_serial_baseline() {
    let opts = EngineOptions {
        max_dim: 1,
        threads: 4,
        ..Default::default()
    };
    let session = Session::new(opts);
    let datasets: Vec<MetricData> = (0..6).map(|i| cloud(24 + 2 * i, 3, 100 + i as u64)).collect();
    let handles: Vec<_> = datasets
        .iter()
        .map(|d| session.ingest(d, f64::INFINITY).unwrap())
        .collect();
    let serial: Vec<_> = handles
        .iter()
        .map(|h| {
            response_bits(
                &session
                    .query(h, &PhRequest::at(f64::INFINITY))
                    .unwrap(),
            )
        })
        .collect();
    let concurrent: Vec<_> = std::thread::scope(|scope| {
        let joins: Vec<_> = handles
            .iter()
            .map(|h| {
                let session = &session;
                scope.spawn(move || {
                    response_bits(
                        &session
                            .query(h, &PhRequest::at(f64::INFINITY))
                            .unwrap(),
                    )
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    assert_eq!(concurrent, serial);
    assert_eq!(session.stats().filtration_builds, handles.len() as u64);
}

/// Concurrent `run_batch` calls — a large batch and several small ones
/// in flight together — all bit-identical to fresh one-shot runs.
#[test]
fn concurrent_batches_match_fresh_runs() {
    let data = cloud(26, 3, 777);
    let opts = EngineOptions {
        max_dim: 2,
        threads: 4,
        ..Default::default()
    };
    let session = Session::new(opts.clone());
    let handle = session.ingest(&data, f64::INFINITY).unwrap();
    let big: Vec<PhRequest> = (1..=10).map(|i| PhRequest::at(0.09 * i as f64)).collect();
    let small: Vec<PhRequest> = vec![PhRequest::at(0.3), PhRequest::at(0.6)];
    let (big_out, small_out) = std::thread::scope(|scope| {
        let s = &session;
        let h = &handle;
        let a = scope.spawn(move || s.run_batch(h, &big).unwrap());
        let b = scope.spawn(move || s.run_batch(h, &small).unwrap());
        (a.join().unwrap(), b.join().unwrap())
    });
    for resp in big_out.iter().chain(small_out.iter()) {
        let fresh = compute_ph(&data, resp.tau, &opts);
        assert_eq!(
            response_bits(resp),
            diagram_bits(&fresh.diagram),
            "tau={}: batch response deviates from fresh run",
            resp.tau
        );
    }
}

/// Typed request errors hold under concurrency: bad requests racing
/// good ones poison nothing and return the right `DoryError` variants.
#[test]
fn typed_errors_survive_concurrent_traffic() {
    let data = cloud(20, 3, 31);
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let handle = session.ingest(&data, f64::INFINITY).unwrap();
    std::thread::scope(|scope| {
        let s = &session;
        let h = &handle;
        let good = scope.spawn(move || {
            for _ in 0..4 {
                s.query(h, &PhRequest::at(0.5)).unwrap();
            }
        });
        let nan = scope.spawn(move || {
            for _ in 0..4 {
                let e = s.query(h, &PhRequest::at(f64::NAN)).unwrap_err();
                assert!(matches!(e, DoryError::Request(_)), "{e}");
            }
        });
        let neg = scope.spawn(move || {
            for _ in 0..4 {
                let e = s.query(h, &PhRequest::at(-1.0)).unwrap_err();
                assert!(matches!(e, DoryError::Request(_)), "{e}");
            }
        });
        good.join().unwrap();
        nan.join().unwrap();
        neg.join().unwrap();
    });
    // Refused requests were never counted as served queries.
    assert_eq!(session.stats().queries, 4);
}

/// The wire front under contention: interleaved tenants drive
/// `Server::handle_line` from racing threads. Every response must stay
/// well-formed, cache hits must deduplicate the shared dataset, and the
/// betti numbers must match a direct session query.
#[test]
fn server_handles_racing_tenants() {
    let srv = Server::new(
        EngineOptions {
            max_dim: 1,
            threads: 2,
            ..Default::default()
        },
        256 << 20,
    );
    // Serial warm-up ingest so every tenant's ingest is a cache hit and
    // all threads race on the same handle.
    let ingest = r#"{"id":0,"tenant":"warm","method":"ingest","dataset":{"kind":"circle","n":40,"seed":5}}"#;
    let (resp, _) = srv.handle_line(ingest);
    let key = resp
        .get("ok")
        .and_then(|o| o.get("handle"))
        .and_then(|h| h.as_str())
        .unwrap()
        .to_string();
    let direct = {
        let probe = format!("{{\"id\":0,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}");
        let (r, _) = srv.handle_line(&probe);
        r.get("ok").unwrap().get("betti").unwrap().render()
    };
    std::thread::scope(|scope| {
        for t in 0..6 {
            let srv = &srv;
            let key = &key;
            let direct = &direct;
            scope.spawn(move || {
                let tenant = format!("t{t}");
                for i in 0..5 {
                    let (r, stop) = srv.handle_line(&format!(
                        "{{\"id\":{i},\"tenant\":\"{tenant}\",\"method\":\"ingest\",\"dataset\":{{\"kind\":\"circle\",\"n\":40,\"seed\":5}}}}"
                    ));
                    assert!(!stop);
                    assert_eq!(
                        r.get("ok").unwrap().get("cached").unwrap().as_bool(),
                        Some(true)
                    );
                    let (r, _) = srv.handle_line(&format!(
                        "{{\"id\":{i},\"tenant\":\"{tenant}\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}"
                    ));
                    assert_eq!(
                        r.get("ok").unwrap().get("betti").unwrap().render(),
                        *direct
                    );
                    // A malformed request racing the good ones: typed
                    // error, loop and session unharmed.
                    let (r, _) = srv.handle_line(&format!(
                        "{{\"id\":{i},\"tenant\":\"{tenant}\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":-3}}"
                    ));
                    assert_eq!(
                        r.get("error").unwrap().get("kind").unwrap().as_str(),
                        Some("Request")
                    );
                }
            });
        }
    });
    let summary = srv.summary_json();
    let session = summary.get("session").unwrap();
    // 1 warm-up build; 30 tenant ingests were all cache hits.
    assert_eq!(session.get("filtration_builds").unwrap().as_usize(), Some(1));
    assert_eq!(session.get("queries").unwrap().as_usize(), Some(1 + 30));
    let t0 = summary.get("tenants").unwrap().get("t0").unwrap();
    assert_eq!(t0.get("cache_hits").unwrap().as_usize(), Some(5));
    assert_eq!(t0.get("errors").unwrap().as_usize(), Some(5));
}

/// Cache-eviction determinism end to end: a tight budget server evicts
/// in pure LRU order, so re-running the same request sequence yields
/// the same eviction keys and the same final cache contents.
#[test]
fn cache_eviction_is_deterministic_across_runs() {
    let run = || {
        let srv = Server::new(
            EngineOptions {
                max_dim: 1,
                threads: 1,
                ..Default::default()
            },
            1, // 1-byte budget: every insert evicts the previous handle
        );
        let mut log = Vec::new();
        for seed in [1u64, 2, 3] {
            let (r, _) = srv.handle_line(&format!(
                "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"kind\":\"circle\",\"n\":24,\"seed\":{seed}}}}}"
            ));
            let ok = r.get("ok").unwrap();
            log.push((
                ok.get("handle").unwrap().as_str().unwrap().to_string(),
                ok.get("evicted").unwrap().render(),
            ));
        }
        log
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    // Each insert evicted exactly the previous key.
    assert_eq!(a[0].1, "[]");
    assert_eq!(a[1].1, format!("[\"{}\"]", a[0].0));
    assert_eq!(a[2].1, format!("[\"{}\"]", a[1].0));
}

/// The serve loop itself over an in-memory pipe: interleaved tenants,
/// a shared dataset, a batch, an error, a shutdown — responses arrive
/// in request order with ids echoed, and the summary trailer closes it.
#[test]
fn serve_loop_interleaves_tenants_over_a_pipe() {
    let srv = Server::new(
        EngineOptions {
            max_dim: 1,
            threads: 2,
            ..Default::default()
        },
        256 << 20,
    );
    let mut out = Vec::new();
    let script = concat!(
        r#"{"id":1,"tenant":"a","method":"ingest","dataset":{"kind":"figure-eight","n":36,"seed":2}}"#,
        "\n",
        r#"{"id":2,"tenant":"b","method":"ingest","dataset":{"kind":"figure-eight","n":36,"seed":2}}"#,
        "\n",
        r#"{"id":3,"tenant":"b","method":"query","handle":"hmissing","tau":0.5}"#,
        "\n",
        r#"{"id":4,"method":"stats"}"#,
        "\n",
        r#"{"id":5,"method":"shutdown"}"#,
        "\n",
    );
    let served = srv
        .serve(std::io::Cursor::new(script.to_string()), &mut out)
        .unwrap();
    assert_eq!(served, 5);
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 6); // 5 responses + summary trailer
    for (i, l) in lines[..5].iter().enumerate() {
        assert_eq!(l.get("id").unwrap().as_usize(), Some(i + 1));
    }
    assert_eq!(
        lines[1].get("ok").unwrap().get("cached").unwrap().as_bool(),
        Some(true)
    );
    assert_eq!(
        lines[2].get("error").unwrap().get("kind").unwrap().as_str(),
        Some("Request")
    );
    let summary = lines[5].get("summary").unwrap();
    assert_eq!(
        summary
            .get("cache")
            .unwrap()
            .get("hits")
            .unwrap()
            .as_usize(),
        Some(1)
    );
}
