//! Micro-benchmarks of the hot paths — the §Perf iteration harness.
//!
//!     cargo bench --bench micro_hotpaths
//!
//! Covers: edge_order lookup (sparse binary search vs DoryNS dense),
//! coboundary cursor throughput (FindSmallestt/FindNextt), bucket-table
//! reduction steps, F1 construction, H0 union-find, and the thread-pool
//! dispatch overhead. Numbers feed EXPERIMENTS.md §Perf.

use std::time::Instant;

use dory::bench_support as bs;
use dory::coboundary::TriCursor;
use dory::datasets;
use dory::filtration::{EdgeFiltration, FiltrationStats, FrontendOptions, Neighborhoods, SimdMode};
use dory::homology::EngineOptions;
use dory::reduction::pool::ThreadPool;
use dory::util::json::Json;
use dory::util::rng::Pcg32;

fn timeit<F: FnMut() -> u64>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup + measure; returns ns/op and prints a row.
    let mut sink = 0u64;
    for _ in 0..iters.min(3) {
        sink = sink.wrapping_add(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let dt = t0.elapsed().as_secs_f64();
    let per = dt / iters as f64;
    println!("{label:<42} {:>12.1} ns/op   (sink {sink:x})", per * 1e9);
    per * 1e9
}

fn main() {
    let _ = bs::parse_scale();
    let data = datasets::torus4(4000, 3);
    let f = EdgeFiltration::build(&data, 0.3);
    let nb_sparse = Neighborhoods::build(&f, false);
    let nb_dense = Neighborhoods::build(&f, true);
    let ne = f.n_edges() as u32;
    println!("workload: torus4 n=4000 tau=0.3, n_e={ne}\n");
    let mut out = Json::obj();

    // --- edge_order lookup: the §4.6 sparse-vs-dense tradeoff ------------
    let mut rng = Pcg32::new(1);
    let queries: Vec<(u32, u32)> = (0..100_000)
        .map(|_| {
            let e = rng.gen_range(ne);
            f.edges[e as usize]
        })
        .collect();
    let q1 = timeit("edge_order hit (sparse binsearch)", 20, || {
        let mut acc = 0u64;
        for &(a, b) in &queries {
            acc = acc.wrapping_add(nb_sparse.edge_order(a, b).unwrap_or(0) as u64);
        }
        acc
    }) / queries.len() as f64;
    let q2 = timeit("edge_order hit (dense table, DoryNS)", 20, || {
        let mut acc = 0u64;
        for &(a, b) in &queries {
            acc = acc.wrapping_add(nb_dense.edge_order(a, b).unwrap_or(0) as u64);
        }
        acc
    }) / queries.len() as f64;
    out = out.field("edge_order_sparse_ns", q1).field("edge_order_dense_ns", q2);

    // --- coboundary cursor enumeration ------------------------------------
    let edges: Vec<u32> = (0..ne).step_by((ne as usize / 2000).max(1)).collect();
    let c1 = timeit("TriCursor full coboundary walk / edge", 5, || {
        let mut acc = 0u64;
        for &e in &edges {
            let (a, b) = f.edges[e as usize];
            let mut c = TriCursor::find_smallest(&nb_sparse, e, a, b);
            while !c.cur.is_none() {
                acc = acc.wrapping_add(c.cur.pack());
                c.find_next(&nb_sparse);
            }
        }
        acc
    }) / edges.len() as f64;
    out = out.field("coboundary_walk_per_edge_ns", c1);

    // --- full engine single-thread vs 4 threads ---------------------------
    for (label, threads) in [("engine 1 thread (H1)", 1usize), ("engine 4 threads (H1)", 4)] {
        let opts = EngineOptions {
            max_dim: 1,
            threads,
            ..Default::default()
        };
        let t0 = Instant::now();
        let r = dory::homology::compute_ph_from_filtration(&f, &opts);
        let dt = t0.elapsed().as_secs_f64();
        println!("{label:<42} {dt:>11.3} s    (pairs {})", r.stats.h1.pairs);
        out = out.field(&format!("{label} s"), dt);
    }

    // --- thread pool dispatch overhead -------------------------------------
    let pool = ThreadPool::new(4);
    let d = timeit("pool.run dispatch+join (empty job)", 2000, || {
        pool.run(|_| {});
        0
    });
    out = out.field("pool_dispatch_ns", d);
    // Work-stealing task machinery: 1024 single-index tasks per
    // generation (worst-case queue traffic; real pushes use column
    // ranges, so per-task cost is amortized far below this).
    let d2 = timeit("pool.run_stealing 1024 tasks (empty)", 500, || {
        pool.run_stealing(1024, 1, |_t, _r| {});
        0
    }) / 1024.0;
    out = out.field("pool_steal_task_ns", d2);
    // Pipelined submit/wait with caller-side work in between — the
    // serial-commit overlap pattern of the scheduler.
    let overlap_sink = std::sync::atomic::AtomicU64::new(0);
    let d3 = timeit("pool.submit + caller work + wait", 1000, || {
        // SAFETY: the ticket is waited on before the closure returns.
        let t = unsafe {
            pool.submit_stealing(64, 8, |_t, r| {
                for i in r {
                    overlap_sink.fetch_add(i as u64, std::sync::atomic::Ordering::Relaxed);
                }
            })
        };
        let mut acc = 0u64;
        for i in 0..512u64 {
            acc = acc.wrapping_add(i * i);
        }
        t.wait();
        acc
    });
    out = out.field("pool_pipelined_dispatch_ns", d3);
    let ps = pool.stats();
    println!(
        "{:<42} {:>12} gens, {} tasks, {} steals",
        "pool cumulative", ps.generations, ps.tasks, ps.steals
    );

    // --- sharded H2* enumeration on the pool --------------------------------
    // Smoke assertion for CI: the H2* (and H1*) column enumeration must
    // execute as work-stealing tasks on the pool workers — if the
    // enumeration span ever falls back to the scheduler thread the shard
    // stats go to zero and this bench exits nonzero.
    let sphere = datasets::sphere(150, 1.0, 0.0, 1);
    let fs = EdgeFiltration::build(&sphere, 1.0);
    let opts = EngineOptions {
        max_dim: 2,
        threads: 4,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = dory::homology::compute_ph_from_filtration(&fs, &opts);
    let dt = t0.elapsed().as_secs_f64();
    let s2 = r.stats.h2_sched;
    println!(
        "{:<42} {dt:>11.3} s    (H2* enum: {} shards, {} cols, busy {:.3}s, blocked {:.3}s)",
        "engine 4 threads (H2, sphere150)",
        s2.enum_shards,
        s2.enum_columns,
        s2.enum_busy_ns as f64 * 1e-9,
        s2.enum_block_ns as f64 * 1e-9,
    );
    // Deterministic counters only (shard/column counts, not measured
    // nanoseconds) so a coarse platform clock cannot flake this CI gate.
    assert!(
        s2.enum_shards > 0 && s2.enum_columns > 0,
        "H2* column enumeration ran on the scheduler thread (no pool shards recorded)"
    );
    assert!(
        r.stats.h1_sched.enum_shards > 0 && r.stats.h1_sched.enum_columns > 0,
        "H1* column enumeration ran on the scheduler thread (no pool shards recorded)"
    );

    // --- apparent-pair shortcut on the sphere workload ----------------------
    // CI gate for the enumeration-time shortcut: a nonzero fraction of
    // the H2* columns surviving clearing must be resolved in-shard
    // (apparent pairs), never entering a BucketTable. Counter-based and
    // deterministic — a zero skip rate means the shortcut regressed.
    let h2 = &r.stats.h2;
    let h2_skip = h2.skip_rate();
    println!(
        "{:<42} {:>10} / {:<8} ({:.1}% skipped, trivial total {})",
        "H2* shortcut pairs (sphere150)",
        h2.shortcut_pairs,
        h2.columns + h2.shortcut_pairs,
        h2_skip * 100.0,
        h2.trivial_pairs,
    );
    assert!(
        h2.shortcut_pairs > 0 && h2_skip > 0.0,
        "H2*-on-sphere skip rate is zero — the apparent-pair shortcut is inactive"
    );
    assert!(
        r.stats.h1.shortcut_pairs > 0,
        "H1*-on-sphere skip rate is zero — the apparent-pair shortcut is inactive"
    );
    // Exact-fallback comparison (shortcut off): same instance, every
    // trivial pair resolved inside the reduction instead.
    let t0 = Instant::now();
    let r_off = dory::homology::compute_ph_from_filtration(
        &fs,
        &EngineOptions {
            shortcut: false,
            ..opts.clone()
        },
    );
    let dt_off = t0.elapsed().as_secs_f64();
    println!(
        "{:<42} {dt_off:>11.3} s    (shortcut off; trivial in-reduction {})",
        "engine 4 threads (H2, sphere150, no-skip)", r_off.stats.h2.trivial_pairs
    );
    assert_eq!(
        h2.trivial_pairs, r_off.stats.h2.trivial_pairs,
        "trivial-pair totals must be invariant under the shortcut"
    );
    out = out
        .field("h2_engine_4t_s", dt)
        .field("h2_engine_4t_noshortcut_s", dt_off)
        .field("h2_enum_shards", s2.enum_shards as i64)
        .field("h2_enum_columns", s2.enum_columns as i64)
        .field("h2_enum_busy_s", s2.enum_busy_ns as f64 * 1e-9)
        .field("h2_enum_block_s", s2.enum_block_ns as f64 * 1e-9)
        .field("h2_shortcut_pairs", h2.shortcut_pairs)
        .field("h2_skip_rate", h2_skip)
        .field("h1_shortcut_pairs", r.stats.h1.shortcut_pairs)
        .field("h1_skip_rate", r.stats.h1.skip_rate())
        .field("max_rss_bytes", dory::util::memtrack::max_rss_bytes());

    // --- session batch amortization -----------------------------------------
    // CI gate for the service mode: a batch of 8 τ-queries served from
    // ONE Session ingest must beat 8 cold one-shot runs (each cold run
    // pays the full O(n²) distance pass + sort + CSR build again). The
    // answers must also be bit-identical, and the session counters must
    // show exactly one filtration/CSR build for the whole batch. A
    // ratio <= 1.0 means the prefix-truncation path regressed into
    // rebuilding.
    let svc_data = datasets::sphere(900, 1.0, 0.0, 5);
    let svc_taus = [0.08, 0.10, 0.12, 0.15, 0.18, 0.20, 0.22, 0.25];
    let svc_opts = EngineOptions {
        max_dim: 1,
        threads: 4,
        ..Default::default()
    };
    let t0 = Instant::now();
    let session = dory::homology::Session::new(svc_opts.clone());
    let handle = session.ingest(&svc_data, 0.25).expect("session ingest");
    let reqs: Vec<dory::homology::PhRequest> = svc_taus
        .iter()
        .map(|&t| dory::homology::PhRequest::at(t))
        .collect();
    let responses = session.run_batch(&handle, &reqs).expect("session batch");
    let t_session = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for (&tau, resp) in svc_taus.iter().zip(&responses) {
        let cold = dory::homology::compute_ph(&svc_data, tau, &svc_opts);
        assert!(
            cold.diagram.multiset_eq(&resp.result.diagram, 0.0),
            "session answer at tau={tau} deviates from the cold run"
        );
    }
    let t_cold = t0.elapsed().as_secs_f64();
    let amortization = t_cold / t_session.max(1e-12);
    let st = session.stats();
    println!(
        "{:<42} {t_session:>11.3} s    (8 cold runs {t_cold:.3}s -> x{amortization:.2}; {} F1 builds, {} CSR builds)",
        "session batch-of-8 (sphere900, H1)", st.filtration_builds, st.nb_builds
    );
    assert_eq!(
        (st.filtration_builds, st.nb_builds),
        (1, 1),
        "a batch must amortize exactly one build"
    );
    assert!(
        amortization > 1.0,
        "session batch-of-8 ({t_session:.3}s) must beat 8 cold runs ({t_cold:.3}s): \
         amortization {amortization:.3} <= 1.0 — the shared-ingest path regressed"
    );
    out = out
        .field("session_batch8_s", t_session)
        .field("session_cold8_s", t_cold)
        .field("session_amortization", amortization)
        .field("session_f1_builds", st.filtration_builds)
        .field("session_nb_builds", st.nb_builds);

    // --- concurrent queries on one handle ------------------------------------
    // CI gate for the concurrent-serving mode: 8 threads issuing the
    // same query through `&self` on ONE session/handle must finish in
    // less than 8x the single-query wall time — i.e. the shared pool's
    // multi-generation scheduler actually interleaves the queries
    // instead of serializing them behind a lock. Answers must stay
    // bit-identical to the serial response. The bound is deliberately
    // loose (any overlap at all beats 8x) so platform noise cannot
    // flake it; the speedup itself is exported for the trajectory.
    let conc_req = dory::homology::PhRequest::at(0.20);
    let serial_resp = session.query(&handle, &conc_req).expect("serial query");
    let serial_bits: Vec<(u64, u64)> = {
        let d = &serial_resp.result.diagram;
        (0..=d.max_dim())
            .flat_map(|k| d.points(k).iter().map(|p| (p.birth.to_bits(), p.death.to_bits())))
            .collect()
    };
    // Best of 3 so a cold first run cannot inflate the budget's base.
    let mut t_single = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        session.query(&handle, &conc_req).expect("single query");
        t_single = t_single.min(t0.elapsed().as_secs_f64());
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let session = &session;
            let handle = &handle;
            let conc_req = &conc_req;
            let serial_bits = &serial_bits;
            scope.spawn(move || {
                let resp = session.query(handle, conc_req).expect("concurrent query");
                let d = &resp.result.diagram;
                let bits: Vec<(u64, u64)> = (0..=d.max_dim())
                    .flat_map(|k| {
                        d.points(k).iter().map(|p| (p.birth.to_bits(), p.death.to_bits()))
                    })
                    .collect();
                assert_eq!(
                    &bits, serial_bits,
                    "concurrent query deviates from the serial response"
                );
            });
        }
    });
    let t_conc = t0.elapsed().as_secs_f64();
    let speedup = 8.0 * t_single / t_conc.max(1e-12);
    println!(
        "{:<42} {t_conc:>11.3} s    (single {t_single:.3}s -> x{speedup:.2} vs 8x-serial)",
        "8 concurrent queries, one handle"
    );
    assert!(
        t_conc < 8.0 * t_single,
        "8 concurrent queries ({t_conc:.3}s) must beat 8x the single-query time \
         ({:.3}s) — the shared pool serialized the tenants",
        8.0 * t_single
    );
    out = out
        .field("single_query_s", t_single)
        .field("concurrent8_s", t_conc)
        .field("concurrency_speedup", speedup);

    // --- F1 construction ----------------------------------------------------
    let t0 = Instant::now();
    let f2 = EdgeFiltration::build(&data, 0.3);
    let dt = t0.elapsed().as_secs_f64();
    println!("{:<42} {dt:>11.3} s    (n_e {})", "F1 build (dist+sort)", f2.n_edges());
    out = out.field("f1_build_s", dt);

    // --- pooled filtration front-end ----------------------------------------
    // CI gate for the parallel front-end: on a 4-thread engine at
    // infinite tau the distance kernel, the key sort and the CSR fill
    // must all execute as pool work (nonzero tile/chunk counters), and
    // the enclosing-radius truncation must prune a nonzero number of
    // edges on the sphere workload (r_enc < the diameter for a generic
    // sample). Counter-based and deterministic — zero means the
    // front-end fell back to the scheduler thread or the truncation
    // regressed.
    let sphere_fe = datasets::sphere(300, 1.0, 0.0, 2);
    let engine = dory::homology::Engine::new(EngineOptions {
        max_dim: 0,
        threads: 4,
        ..Default::default()
    });
    let t0 = Instant::now();
    let r_fe = engine.compute_metric(&sphere_fe, f64::INFINITY);
    let dt_fe = t0.elapsed().as_secs_f64();
    let fs = r_fe.stats.filtration;
    println!(
        "{:<42} {dt_fe:>11.3} s    ({} tiles, {} sort chunks, {} nb chunks)",
        "front-end 4 threads (sphere300, tau=inf)", fs.tiles, fs.sort_chunks, fs.nb_chunks
    );
    println!(
        "{:<42} {:>10} / {:<10} ({} pruned at r_enc={:.4})",
        "enclosing-radius pruning (sphere300)",
        fs.edges_kept,
        fs.edges_considered,
        fs.edges_pruned,
        fs.enclosing_radius,
    );
    assert!(
        fs.tiles > 0,
        "front-end distance pass ran on the scheduler thread (no pool tiles recorded)"
    );
    assert!(
        fs.sort_chunks > 0 && fs.nb_chunks > 0,
        "front-end sort/CSR phases ran on the scheduler thread"
    );
    assert!(
        fs.edges_pruned > 0,
        "enclosing-radius pruning is inactive on the sphere workload"
    );
    assert_eq!(fs.edges_considered, fs.edges_kept + fs.edges_pruned);
    // Byte-identity smoke vs the serial reference at tau = r_enc.
    let serial_fe = EdgeFiltration::build(&sphere_fe, fs.enclosing_radius);
    assert_eq!(
        serial_fe.n_edges() as u64,
        fs.edges_kept,
        "pooled front-end kept set deviates from the serial build at r_enc"
    );
    out = out
        .field("f1_frontend_s", dt_fe)
        .field("f1_dist_s", fs.dist_ns as f64 * 1e-9)
        .field("f1_sort_s", fs.sort_ns as f64 * 1e-9)
        .field("f1_nb_s", fs.nb_ns as f64 * 1e-9)
        .field("f1_tiles", fs.tiles as f64)
        .field("f1_sort_chunks", fs.sort_chunks as f64)
        .field("f1_nb_chunks", fs.nb_chunks as f64)
        .field("f1_considered", fs.edges_considered as f64)
        .field("f1_kept", fs.edges_kept as f64)
        .field("f1_pruned", fs.edges_pruned as f64)
        .field("f1_prune_rate", fs.edges_pruned as f64 / fs.edges_considered as f64)
        .field("f1_r_enc", fs.enclosing_radius);

    // --- large sparse ingest: streamed vs in-memory --------------------------
    // CI gate for the million-point ingestion path: a 150k-edge sparse
    // file ingested through the budgeted streaming reader must (a) spill
    // sorted runs to disk, (b) peak BELOW the in-memory reader's heap
    // (which holds the full entry vector and the full key vector at
    // once), and (c) produce the identical edge set. Counter-based and
    // deterministic; the peaks come from the counting allocator, not RSS.
    let spath = std::env::temp_dir().join("dory-bench-stream.coo");
    {
        use std::io::Write as _;
        let mut w = std::io::BufWriter::new(std::fs::File::create(&spath).expect("bench tmp"));
        for i in 0..150_000u32 {
            let d = 1.0 + (i % 997) as f64 / 1000.0;
            writeln!(w, "{} {} {d}", i, i + 1).expect("bench tmp write");
        }
    }
    let stream_session = dory::homology::Session::new(EngineOptions {
        max_dim: 0,
        threads: 4,
        ..Default::default()
    });
    dory::util::memtrack::reset_peak();
    let t0 = Instant::now();
    let smd = dory::io::read_sparse_coo(&spath).expect("bench read");
    let h_mem = stream_session.ingest(&smd, 3.0).expect("bench ingest");
    let inmem_s = t0.elapsed().as_secs_f64();
    let inmem_peak = dory::util::memtrack::section_peak_bytes();
    let inmem_edges = h_mem.n_edges();
    drop(h_mem);
    drop(smd);
    dory::util::memtrack::reset_peak();
    let t0 = Instant::now();
    let (h_s, sstats) = stream_session
        .ingest_sparse_file(
            &spath,
            3.0,
            &dory::io::stream::StreamOptions {
                chunk_lines: 8192,
                budget_bytes: 1 << 20,
                spill_dir: None,
                strict: false,
            },
        )
        .expect("bench stream ingest");
    let stream_s = t0.elapsed().as_secs_f64();
    let stream_peak = dory::util::memtrack::section_peak_bytes();
    println!(
        "{:<42} {stream_s:>11.3} s    (peak {} vs in-memory {} in {inmem_s:.3}s; {} runs spilled)",
        "streamed ingest (150k edges, 1 MiB budget)",
        dory::util::memtrack::fmt_bytes(stream_peak),
        dory::util::memtrack::fmt_bytes(inmem_peak),
        sstats.spilled_runs,
    );
    assert_eq!(h_s.n_edges(), inmem_edges, "streamed edge set deviates");
    assert!(sstats.spilled_runs > 0, "a 2.4 MB key stream must spill at 1 MiB");
    assert!(
        stream_peak < inmem_peak,
        "streamed ingest peak {stream_peak} must stay below the in-memory peak {inmem_peak}"
    );
    drop(h_s);
    let _ = std::fs::remove_file(&spath);
    out = out
        .field("stream_peak_rss_bytes", stream_peak)
        .field("inmem_peak_rss_bytes", inmem_peak)
        .field("stream_ingest_s", stream_s)
        .field("inmem_ingest_s", inmem_s)
        .field("stream_spilled_runs", sstats.spilled_runs)
        .field("stream_staging_peak_bytes", sstats.staging_peak_bytes);

    // --- k-NN net-graph front-end -------------------------------------------
    // CI gate for the sparse-neighbor-graph kernel: uncapped, the
    // cell-pair scan must reproduce the dense thresholded edge set
    // exactly (triangle-inequality pruning is conservative); capped, it
    // must keep strictly fewer entries. Counter-based and deterministic.
    let knn_md = datasets::circle(1200, 1.0, 0.05, 7);
    let dory::geometry::MetricData::Points(knn_pc) = &knn_md else {
        unreachable!("circle is a point cloud");
    };
    let knn_tau = 0.6;
    let t0 = Instant::now();
    let cover = dory::filtration::sparsify::NetCover::build(knn_pc, 140, 0.0, 3);
    let exact = dory::filtration::sparsify::net_graph_edges(knn_pc, &cover, knn_tau, 0, None);
    let knn_build_s = t0.elapsed().as_secs_f64();
    let dense = EdgeFiltration::build(&knn_md, knn_tau);
    let capped = dory::filtration::sparsify::net_graph_edges(knn_pc, &cover, knn_tau, 6, None);
    println!(
        "{:<42} {knn_build_s:>11.3} s    (exact {} == dense {}, capped k=6 {})",
        "net-graph kernel (circle1200, 140 cells)",
        exact.entries.len(),
        dense.n_edges(),
        capped.entries.len(),
    );
    assert_eq!(
        exact.entries.len(),
        dense.n_edges(),
        "uncapped net-graph kernel deviates from the dense edge set"
    );
    assert!(
        capped.entries.len() < exact.entries.len(),
        "k-NN cap kept every edge — capping is inactive"
    );
    out = out
        .field("knn_build_s", knn_build_s)
        .field("knn_edges_kept", capped.entries.len())
        .field("knn_edges_exact", exact.entries.len());

    // --- SIMD distance kernel: scalar vs auto -------------------------------
    // CI gate for the vector microkernel: on a dense sphere the
    // runtime-selected kernel (AVX2/NEON when the host has it) must beat
    // the scalar loop on the distance pass while emitting bit-identical
    // edges. The speedup assert only fires when a vector kernel was
    // actually selected — on a scalar-only host both runs are the same
    // code path and the ratio is noise.
    let simd_data = datasets::sphere(1200, 1.0, 0.0, 13);
    let run_kernel = |mode: SimdMode| {
        let fe = FrontendOptions {
            tile: 0,
            enclosing: true,
            simd: mode,
        };
        let mut best_ns = u64::MAX;
        let mut kernel = "";
        let mut filt = None;
        for _ in 0..3 {
            let mut s = FiltrationStats::default();
            let g = EdgeFiltration::build_pooled(
                &simd_data,
                f64::INFINITY,
                Some(&pool),
                &fe,
                &mut s,
            );
            best_ns = best_ns.min(s.dist_ns);
            kernel = s.dist_kernel;
            filt = Some(g);
        }
        (filt.unwrap(), best_ns, kernel)
    };
    let (f_scalar, scalar_dist_ns, k_scalar) = run_kernel(SimdMode::Scalar);
    let (f_simd, simd_dist_ns, k_simd) = run_kernel(SimdMode::Auto);
    assert_eq!(k_scalar, "scalar");
    assert_eq!(f_scalar.edges, f_simd.edges, "SIMD kernel changed the edge set");
    let sb: Vec<u64> = f_scalar.values.iter().map(|v| v.to_bits()).collect();
    let vb: Vec<u64> = f_simd.values.iter().map(|v| v.to_bits()).collect();
    assert_eq!(sb, vb, "SIMD kernel changed a distance bit");
    assert_eq!(f_scalar.tau_max.to_bits(), f_simd.tau_max.to_bits());
    let simd_speedup = scalar_dist_ns as f64 / (simd_dist_ns.max(1)) as f64;
    println!(
        "{:<42} {:>11.3} ms   (scalar {:.3} ms -> x{simd_speedup:.2}, kernel {k_simd})",
        "SIMD distance pass (sphere1200, tau=inf)",
        simd_dist_ns as f64 * 1e-6,
        scalar_dist_ns as f64 * 1e-6,
    );
    if k_simd != "scalar" {
        assert!(
            simd_speedup > 1.0,
            "vector kernel {k_simd} ({simd_dist_ns} ns) failed to beat the scalar \
             distance pass ({scalar_dist_ns} ns): speedup {simd_speedup:.3} <= 1.0"
        );
    }
    out = out
        .field("scalar_dist_ns", scalar_dist_ns as f64)
        .field("simd_dist_ns", simd_dist_ns as f64)
        .field("dist_kernel", k_simd)
        .field("simd_speedup", simd_speedup);

    // --- dense streaming through the spill store ----------------------------
    // CI gate for the budgeted dense ingest: a sphere whose kept key
    // stream (~3.9 MB) exceeds a 256 KiB budget must spill sorted runs,
    // with resident staging tracking budget + one wave of tile scratch
    // (counting allocator) instead of the full key vector, and the edge
    // set identical to the in-memory ingest. Diagram bit-identity across
    // budgets is pinned by the streaming test suite.
    let ds_n = 700usize;
    let ds_tile = 16usize;
    let ds_threads = 4usize;
    let ds_data = datasets::sphere(ds_n, 1.0, 0.0, 17);
    let ds_session = dory::homology::Session::new(EngineOptions {
        max_dim: 0,
        threads: ds_threads,
        f1_tile: ds_tile,
        ..Default::default()
    });
    dory::util::memtrack::reset_peak();
    let t0 = Instant::now();
    let h_dm = ds_session.ingest(&ds_data, f64::INFINITY).expect("dense ingest");
    let dense_inmem_s = t0.elapsed().as_secs_f64();
    let dense_inmem_peak = dory::util::memtrack::section_peak_bytes();
    let dense_edges = h_dm.n_edges();
    drop(h_dm);
    dory::util::memtrack::reset_peak();
    let t0 = Instant::now();
    let (h_ds, dstats) = ds_session
        .ingest_streamed(
            &ds_data,
            f64::INFINITY,
            &dory::io::stream::StreamOptions {
                chunk_lines: 0,
                budget_bytes: 256 << 10,
                spill_dir: None,
                strict: false,
            },
        )
        .expect("dense stream ingest");
    let dense_stream_s = t0.elapsed().as_secs_f64();
    let dense_stream_peak = dory::util::memtrack::section_peak_bytes();
    println!(
        "{:<42} {dense_stream_s:>11.3} s    (peak {} vs in-memory {} in {dense_inmem_s:.3}s; {} runs spilled)",
        "dense streamed ingest (sphere700, 256 KiB)",
        dory::util::memtrack::fmt_bytes(dense_stream_peak),
        dory::util::memtrack::fmt_bytes(dense_inmem_peak),
        dstats.spilled_runs,
    );
    assert_eq!(h_ds.edge_source, "dense-stream");
    assert_eq!(h_ds.n_edges(), dense_edges, "dense streamed edge set deviates");
    assert!(
        dstats.spilled_runs > 0,
        "a multi-MB dense key stream must spill at 256 KiB"
    );
    let full_key_bytes = dense_edges * std::mem::size_of::<u128>();
    let wave_scratch = ds_threads * ds_n * 8
        + 2 * ds_threads * ds_tile * ds_n * std::mem::size_of::<u128>();
    assert!(
        dstats.staging_peak_bytes <= (256 << 10) + wave_scratch + 4096,
        "dense staging {} does not track the budget + wave scratch {wave_scratch}",
        dstats.staging_peak_bytes
    );
    assert!(
        dstats.staging_peak_bytes < full_key_bytes,
        "dense staging {} not below the full key vector {full_key_bytes}",
        dstats.staging_peak_bytes
    );
    drop(h_ds);
    out = out
        .field("dense_stream_ingest_s", dense_stream_s)
        .field("dense_inmem_ingest_s", dense_inmem_s)
        .field("dense_stream_peak_bytes", dense_stream_peak)
        .field("dense_inmem_peak_bytes", dense_inmem_peak)
        .field("dense_stream_spilled_runs", dstats.spilled_runs)
        .field("dense_stream_spilled_bytes", dstats.spilled_bytes)
        .field("dense_stream_staging_peak_bytes", dstats.staging_peak_bytes);

    // --- feature products ---------------------------------------------------
    // CI gates for the features subsystem: (a) the pooled persistence-
    // image raster must be BIT-identical to the serial one (hard assert
    // here) and faster on a 4-thread pool (`feature_image_speedup`,
    // gated in bench-trajectory); (b) the features served by the engine
    // on the golden circle48 input must match the independent Python
    // implementation (`fixtures/circle48.features.txt`) — integer Betti
    // curves exactly, float kernels within 1e-12 relative
    // (`feature_fixture_drift` counts the values that exceed it; the
    // trajectory gate fails on any nonzero count).
    let fixdir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures");
    let hex_f64 = |s: &str| -> f64 {
        f64::from_bits(u64::from_str_radix(s, 16).expect("fixture hex"))
    };
    // The exact fixture input (NOT datasets::circle — transcendentals in
    // the generators may differ from Python's by an ulp; the stored
    // bit patterns are the contract).
    let (fx_tau, fx_points) = {
        let text = std::fs::read_to_string(fixdir.join("circle48.pd.txt")).expect("pd fixture");
        let mut tau = 0.0f64;
        let mut dim = 2usize;
        let mut coords: Vec<f64> = Vec::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("tau") => tau = hex_f64(it.next().unwrap()),
                Some("dim") => dim = it.next().unwrap().parse().unwrap(),
                Some("point") => coords.extend(it.map(|t| hex_f64(t))),
                _ => {}
            }
        }
        (tau, dory::geometry::PointCloud::new(dim, coords))
    };
    // The Python-computed expectations.
    let mut fx_span = 0.0f64;
    let mut fx_grids = (0usize, 0usize, 0usize, 0usize); // betti, levels, lgrid, igrid
    let mut fx_betti: Vec<Vec<u64>> = vec![Vec::new(); 2];
    let mut fx_entropy: Vec<f64> = vec![0.0; 2];
    let mut fx_landscape: Vec<Vec<f64>> = vec![Vec::new(); 2]; // flattened levels·samples
    let mut fx_image: Vec<Vec<f64>> = vec![Vec::new(); 2];
    {
        let text =
            std::fs::read_to_string(fixdir.join("circle48.features.txt")).expect("feature fixture");
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let Some(tag) = it.next() else { continue };
            match tag {
                "span" => fx_span = hex_f64(it.next().unwrap()),
                "betti_grid" => fx_grids.0 = it.next().unwrap().parse().unwrap(),
                "landscape_levels" => fx_grids.1 = it.next().unwrap().parse().unwrap(),
                "landscape_grid" => fx_grids.2 = it.next().unwrap().parse().unwrap(),
                "image_grid" => fx_grids.3 = it.next().unwrap().parse().unwrap(),
                "betti" => {
                    let d: usize = it.next().unwrap().parse().unwrap();
                    fx_betti[d] = it.map(|v| v.parse().unwrap()).collect();
                }
                "entropy" => {
                    let d: usize = it.next().unwrap().parse().unwrap();
                    fx_entropy[d] = hex_f64(it.next().unwrap());
                }
                "landscape" => {
                    let d: usize = it.next().unwrap().parse().unwrap();
                    let _level: usize = it.next().unwrap().parse().unwrap();
                    fx_landscape[d].extend(it.map(|t| hex_f64(t)));
                }
                "image" => {
                    let d: usize = it.next().unwrap().parse().unwrap();
                    let _row: usize = it.next().unwrap().parse().unwrap();
                    fx_image[d].extend(it.map(|t| hex_f64(t)));
                }
                _ => {}
            }
        }
    }
    let feat_session = dory::homology::Session::new(EngineOptions {
        max_dim: 1,
        threads: 4,
        ..Default::default()
    });
    let feat_handle = feat_session
        .ingest(&dory::geometry::MetricData::Points(fx_points), fx_tau)
        .expect("fixture ingest");
    use dory::features::{FeatureSpec, FeatureValue};
    let feat_resp = feat_session
        .query(
            &feat_handle,
            &dory::homology::PhRequest {
                tau: fx_tau,
                features: vec![
                    FeatureSpec::BettiCurve { grid: fx_grids.0 },
                    FeatureSpec::Entropy,
                    FeatureSpec::Landscape {
                        levels: fx_grids.1,
                        grid: fx_grids.2,
                    },
                    FeatureSpec::Image { grid: fx_grids.3 },
                ],
                ..Default::default()
            },
        )
        .expect("fixture feature query");
    let fo = feat_resp.features.as_ref().expect("features served");
    assert_eq!(fo.span.to_bits(), fx_span.to_bits(), "feature span deviates");
    // Drift: values beyond 1e-12 relative of the Python expectation
    // (libm ulp noise passes; anything real does not).
    let mut drift = 0u64;
    let mut max_rel = 0.0f64;
    let mut checked = 0u64;
    fn tally(got: f64, want: f64, drift: &mut u64, max_rel: &mut f64, checked: &mut u64) {
        let rel = (got - want).abs() / want.abs().max(1.0);
        *max_rel = max_rel.max(rel);
        *checked += 1;
        if rel > 1e-12 {
            *drift += 1;
        }
    }
    for item in &fo.items {
        match &item.value {
            FeatureValue::BettiCurve(dims) => {
                for (d, curve) in dims.iter().enumerate() {
                    if curve != &fx_betti[d] {
                        drift += curve.iter().zip(&fx_betti[d]).filter(|(a, b)| a != b).count()
                            as u64;
                    }
                    checked += curve.len() as u64;
                }
            }
            FeatureValue::Entropy(dims) => {
                for (d, &v) in dims.iter().enumerate() {
                    tally(v, fx_entropy[d], &mut drift, &mut max_rel, &mut checked);
                }
            }
            FeatureValue::Landscape(dims) => {
                for (d, levels) in dims.iter().enumerate() {
                    let flat: Vec<f64> = levels.iter().flatten().copied().collect();
                    assert_eq!(flat.len(), fx_landscape[d].len());
                    for (&g, &w) in flat.iter().zip(&fx_landscape[d]) {
                        tally(g, w, &mut drift, &mut max_rel, &mut checked);
                    }
                }
            }
            FeatureValue::Image(dims) => {
                for (d, img) in dims.iter().enumerate() {
                    assert_eq!(img.len(), fx_image[d].len());
                    for (&g, &w) in img.iter().zip(&fx_image[d]) {
                        tally(g, w, &mut drift, &mut max_rel, &mut checked);
                    }
                }
            }
            FeatureValue::Representatives(_) => {}
        }
    }
    println!(
        "{:<42} {:>12} vals   ({} drifted > 1e-12 rel, max rel {max_rel:.2e})",
        "feature fixture cross-check (circle48)", checked, drift
    );
    assert_eq!(drift, 0, "served features drifted from the Python fixture");

    // Pooled image raster vs serial, bit-identity + speedup. A larger
    // raster than the fixture's so the row-band parallelism has real
    // work to amortize dispatch against.
    let (img_pts, _) = dory::features::clamped_sorted(
        &feat_resp.result.diagram,
        0,
        dory::features::feature_span(feat_resp.tau_effective, feat_handle.filtration()),
    );
    let img_grid = 320usize;
    let mut serial_img = Vec::new();
    let t_img_serial = {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            serial_img = dory::features::image::serial(&img_pts, img_grid, fx_span);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let mut pooled_img = Vec::new();
    let t_img_pooled = {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            pooled_img = dory::features::image::pooled(&img_pts, img_grid, fx_span, &pool);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    assert_eq!(
        serial_img.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        pooled_img.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "pooled image raster deviates from serial at the bit level"
    );
    let image_speedup = t_img_serial / t_img_pooled.max(1e-12);
    println!(
        "{:<42} {:>11.3} ms   (serial {:.3} ms -> x{image_speedup:.2}, {img_grid}x{img_grid}, {} pts)",
        "pooled persistence image (4 threads)",
        t_img_pooled * 1e3,
        t_img_serial * 1e3,
        img_pts.len(),
    );
    out = out
        .field("feature_fixture_drift", drift)
        .field("feature_fixture_max_rel_err", max_rel)
        .field("feature_fixture_values", checked)
        .field("feature_image_serial_s", t_img_serial)
        .field("feature_image_pooled_s", t_img_pooled)
        .field("feature_image_speedup", image_speedup)
        .field("feature_pass_s", fo.stats.feature_ns as f64 * 1e-9);

    bs::write_json("micro_hotpaths.json", &out);
}
