//! Figures 19 & 20: o3 H1/H2 persistence-diagram consistency across
//! implementations — the paper's point is that Gudhi *mis-reported*
//! features that do not die, while Dory/Ripser/Eirene agreed.
//!
//!     cargo bench --bench fig19_20_pd_consistency [-- --full]
//!
//! We run the o3 data set through all four of our engines and compare
//! PDs exactly, with special attention to the essential (death = ∞)
//! classes that Gudhi dropped in the paper.

use dory::baselines::{gudhi_like, ripser_like};
use dory::bench_support as bs;
use dory::datasets;
use dory::homology::{compute_ph, EngineOptions};
use dory::util::json::Json;

fn main() {
    let scale = bs::parse_scale();
    let n = match scale {
        bs::Scale::Quick => 768,
        bs::Scale::Full => 8192,
    };
    let tau = 1.0;
    let data = datasets::o3(n, 2);
    println!("o3: n={n}, tau={tau}, d=2");

    let dory = compute_ph(
        &data,
        tau,
        &EngineOptions {
            max_dim: 2,
            threads: 4,
            ..Default::default()
        },
    )
    .diagram;
    let ripser = ripser_like::compute_ph(&data, tau, 2, 8 << 30).expect("ripser-like");
    let gudhi = gudhi_like::compute_ph(&data, tau, 2);

    let mut out = Json::obj();
    for (dim, fig) in [(1usize, "Fig19(H1)"), (2, "Fig20(H2)")] {
        println!("\n== {fig} ==");
        println!(
            "{:<14} {:>8} {:>10}",
            "engine", "finite", "essential"
        );
        for (name, d) in [("dory", &dory), ("ripser-like", &ripser), ("gudhi-like", &gudhi)] {
            println!(
                "{:<14} {:>8} {:>10}",
                name,
                d.finite(dim).len(),
                d.essential_count(dim)
            );
        }
        let consistent_rg = dory.multiset_eq(&ripser, 2e-4);
        let consistent_g = dory.multiset_eq(&gudhi, 1e-9);
        println!(
            "dory == ripser-like: {consistent_rg} | dory == gudhi-like: {consistent_g}"
        );
        out = out.field(
            fig,
            Json::obj()
                .field("dory_finite", dory.finite(dim).len())
                .field("dory_essential", dory.essential_count(dim))
                .field("ripser_essential", ripser.essential_count(dim))
                .field("gudhi_essential", gudhi.essential_count(dim))
                .field("all_consistent", consistent_rg && consistent_g),
        );
    }
    assert!(
        dory.multiset_eq(&ripser, 2e-4) && dory.multiset_eq(&gudhi, 1e-9),
        "PD inconsistency across engines!"
    );
    bs::write_json("fig19_20.json", &out);
    println!("\nAll our engines agree, including on essential classes — the");
    println!("discrepancy the paper observed was a Gudhi reporting issue,");
    println!("which a correct explicit reduction (our gudhi-like) avoids.");
}
