//! Figure 21: percent change in the number of loops (β1) and voids (β2)
//! of the genome upon auxin treatment, per distance threshold.
//!
//!     cargo bench --bench fig21_hic_topology [-- --full]

use dory::bench_support as bs;
use dory::geometry::MetricData;
use dory::hic::{self, Condition, HiCParams};
use dory::homology::{compute_ph, EngineOptions};
use dory::util::json::Json;

fn main() {
    let scale = bs::parse_scale();
    let params = HiCParams {
        n_bins: bs::hic_bins(scale),
        ..Default::default()
    };
    let opts = EngineOptions {
        max_dim: 2,
        threads: 4,
        ..Default::default()
    };
    let mut diagrams = Vec::new();
    for cond in [Condition::Control, Condition::Auxin] {
        let sd = hic::generate(&params, cond);
        println!(
            "{cond:?}: n={} n_e={}",
            params.n_bins,
            sd.entries.len()
        );
        let m = bs::run_engine(&MetricData::Sparse(sd), params.tau_max, &opts);
        println!(
            "  {:.2}s, peak {} | H1 {} | H2 {}",
            m.seconds,
            dory::util::memtrack::fmt_bytes(m.peak_bytes),
            m.result.diagram.points(1).len(),
            m.result.diagram.points(2).len()
        );
        diagrams.push(m.result.diagram);
        // keep a handle for compute_ph import silence
        let _ = compute_ph;
    }
    let (ctrl, aux) = (&diagrams[0], &diagrams[1]);
    let ts: Vec<f64> = (1..=16).map(|k| k as f64 * 25.0).collect();
    println!("\n== Fig 21: percent change (auxin vs control) ==");
    println!(
        "{:>8} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "tau", "b1_ctrl", "b1_aux", "d_b1%", "b2_ctrl", "b2_aux", "d_b2%"
    );
    let pct = |c: usize, a: usize| {
        if c == 0 {
            f64::NAN
        } else {
            (a as f64 - c as f64) / c as f64 * 100.0
        }
    };
    let mut series = Json::arr();
    for &t in &ts {
        let (b1c, b1a) = (ctrl.betti_at(1, t), aux.betti_at(1, t));
        let (b2c, b2a) = (ctrl.betti_at(2, t), aux.betti_at(2, t));
        println!(
            "{t:>8.0} {b1c:>9} {b1a:>9} {:>8.1}% {b2c:>9} {b2a:>9} {:>8.1}%",
            pct(b1c, b1a),
            pct(b2c, b2a)
        );
        series.push(
            Json::obj()
                .field("tau", t)
                .field("b1_control", b1c)
                .field("b1_auxin", b1a)
                .field("b2_control", b2c)
                .field("b2_auxin", b2a),
        );
    }
    // Headline check: strong loop reduction, voids mostly never born.
    let b1 = (ctrl.points(1).len(), aux.points(1).len());
    let b2 = (ctrl.points(2).len(), aux.points(2).len());
    println!(
        "\ntotals: H1 {} -> {} ({:+.1}%), H2 {} -> {} ({:+.1}%)",
        b1.0,
        b1.1,
        pct(b1.0, b1.1),
        b2.0,
        b2.1,
        pct(b2.0, b2.1)
    );
    bs::write_json("fig21.json", &Json::obj().field("series", series));
    assert!(b1.1 < b1.0 / 2, "loops must collapse under auxin");
    assert!(b2.1 < b2.0 / 2, "voids must collapse under auxin");
}
