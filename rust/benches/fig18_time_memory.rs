//! Figure 18: computation time and peak memory bar charts across
//! datasets for Dory, DoryNS and the Ripser-like baseline.
//!
//!     cargo bench --bench fig18_time_memory [-- --full]
//!
//! Emits ASCII bars + `target/bench_out/fig18.json` series (the
//! machine-readable figure data).

use dory::baselines::ripser_like;
use dory::bench_support as bs;
use dory::homology::EngineOptions;
use dory::util::json::Json;
use dory::util::memtrack;

fn main() {
    let scale = bs::parse_scale();
    let suite = bs::suite(scale);
    let mut series = Json::arr();
    let mut rows: Vec<(String, Vec<(String, f64, usize)>)> = Vec::new();
    for ds in &suite {
        let mut entries = Vec::new();
        for (label, dense) in [("dory", false), ("doryNS", true)] {
            let opts = EngineOptions {
                max_dim: ds.max_dim,
                threads: 4,
                dense_lookup: dense,
                ..Default::default()
            };
            let m = bs::run_engine(&ds.data, ds.tau, &opts);
            entries.push((label.to_string(), m.seconds, m.peak_bytes));
        }
        memtrack::reset_peak();
        let t0 = std::time::Instant::now();
        if ripser_like::compute_ph(&ds.data, ds.tau, ds.max_dim, 8 << 30).is_ok() {
            entries.push((
                "ripser-like".into(),
                t0.elapsed().as_secs_f64(),
                memtrack::section_peak_bytes(),
            ));
        }
        rows.push((ds.name.clone(), entries));
    }

    for (name, entries) in &rows {
        println!("\n== {name} ==");
        let tmax = entries.iter().map(|e| e.1).fold(0.0, f64::max);
        let mmax = entries.iter().map(|e| e.2).max().unwrap_or(1);
        for (label, s, b) in entries {
            println!(
                "  {label:<12} time {:>8.2}s |{:<30}|",
                s,
                bs::bar(*s, tmax, 30)
            );
            println!(
                "  {label:<12} mem  {:>8} |{:<30}|",
                memtrack::fmt_bytes(*b),
                bs::bar(*b as f64, mmax as f64, 30)
            );
        }
        let mut j = Json::obj().field("dataset", name.as_str());
        for (label, s, b) in entries {
            j = j.field(
                label,
                Json::obj().field("seconds", *s).field("peak_bytes", *b),
            );
        }
        series.push(j);
    }
    bs::write_json("fig18.json", &Json::obj().field("series", series));
}
