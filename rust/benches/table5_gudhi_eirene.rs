//! Table 5 (App. E): the explicit-representation comparators.
//!
//!     cargo bench --bench table5_gudhi_eirene [-- --full]
//!
//! gudhi-like = simplex tree + standard column reduction;
//! eirene-like = explicit boundary matrix + standard *row* reduction
//! (the memory-heavy profile the paper reports for Eirene). Rows that
//! would blow the memory budget print NA — exactly the paper's NAs.

use dory::bench_support as bs;
use dory::baselines::gudhi_like;
use dory::filtration::{EdgeFiltration, Neighborhoods};
use dory::homology::{engine::count_simplices, EngineOptions};
use dory::reduction::explicit;
use dory::util::json::Json;
use dory::util::memtrack;

/// Refuse explicit representations beyond these many simplices — the
/// paper's NA entries (out-of-memory / >10 min) reproduced as budgets.
const GUDHI_BUDGET: u64 = 2_000_000;
/// The row algorithm scans all columns per row: O(N²) minimum.
const EIRENE_BUDGET: u64 = 30_000;

fn main() {
    let scale = bs::parse_scale();
    println!("== Table 5: explicit-representation baselines ==");
    println!(
        "{:<12} {:>22} {:>22} {:>22}",
        "dataset", "gudhi-like", "eirene-like(row)", "dory (ref)"
    );
    let mut rows = Json::arr();
    for ds in bs::suite(scale) {
        let f = EdgeFiltration::build(&ds.data, ds.tau);
        let nb = Neighborhoods::build(&f, false);
        let n_simpl = count_simplices(&f, &nb, ds.max_dim);

        let dory = {
            let opts = EngineOptions {
                max_dim: ds.max_dim,
                threads: 4,
                ..Default::default()
            };
            let m = bs::run_engine(&ds.data, ds.tau, &opts);
            (bs::cell(m.seconds, m.peak_bytes), m.result.diagram)
        };

        let gudhi_cell = if n_simpl <= GUDHI_BUDGET {
            memtrack::reset_peak();
            let t0 = std::time::Instant::now();
            let d = gudhi_like::compute_ph_from_filtration(&f, &nb, ds.max_dim);
            assert!(
                d.multiset_eq(&dory.1, 1e-9),
                "{}: gudhi-like mismatch",
                ds.name
            );
            bs::cell(t0.elapsed().as_secs_f64(), memtrack::section_peak_bytes())
        } else {
            format!("NA ({n_simpl} simplices)")
        };

        // Eirene stand-in: explicit filtration + standard row algorithm.
        // The row algorithm is O(N^2) scans — cap it harder.
        let eirene_cell = if n_simpl <= EIRENE_BUDGET {
            memtrack::reset_peak();
            let t0 = std::time::Instant::now();
            let ex = explicit::ExplicitFiltration::build(&f, &nb, ds.max_dim + 1);
            let low = explicit::standard_row_algorithm(ex.boundary_matrix());
            let d = explicit::pairs_to_diagram(&ex, &low, ds.max_dim);
            assert!(
                d.multiset_eq(&dory.1, 1e-9),
                "{}: eirene-like mismatch",
                ds.name
            );
            bs::cell(t0.elapsed().as_secs_f64(), memtrack::section_peak_bytes())
        } else {
            "NA".to_string()
        };

        println!(
            "{:<12} {:>22} {:>22} {:>22}",
            ds.name, gudhi_cell, eirene_cell, dory.0
        );
        rows.push(
            Json::obj()
                .field("dataset", ds.name.as_str())
                .field("simplices", n_simpl as f64)
                .field("gudhi_like", gudhi_cell.as_str())
                .field("eirene_like", eirene_cell.as_str())
                .field("dory", dory.0.as_str()),
        );
    }
    bs::write_json("table5.json", &Json::obj().field("rows", rows));
    println!("\npaper shape check: explicit representations pay orders of");
    println!("magnitude more memory and go NA first (Eirene before Gudhi).");
}
