//! Table 4 (App. E): fast implicit column vs implicit row algorithm.
//!
//!     cargo bench --bench table4_implicit_algos [-- --full]
//!
//! Paper shape: the fast column engine wins big where reductions are deep
//! (o3 ~4.5x, torus4(1) ~4.7x, Hi-C ~2x) at comparable memory.

use dory::bench_support as bs;
use dory::homology::{Algorithm, EngineOptions};
use dory::util::json::Json;

fn main() {
    let scale = bs::parse_scale();
    println!("== Table 4: fast implicit column vs implicit row ==");
    println!(
        "{:<12} {:>22} {:>22} {:>8}",
        "dataset", "fast imp. col", "imp. row", "speedup"
    );
    let mut rows = Json::arr();
    for ds in bs::suite(scale) {
        let mut cells = Vec::new();
        let mut secs = Vec::new();
        for algo in [Algorithm::FastColumn, Algorithm::ImplicitRow] {
            let opts = EngineOptions {
                max_dim: ds.max_dim,
                threads: 1, // isolate the reduction engine itself
                algorithm: algo,
                ..Default::default()
            };
            let m = bs::run_engine(&ds.data, ds.tau, &opts);
            cells.push(bs::cell(m.seconds, m.peak_bytes));
            secs.push(m.seconds);
        }
        println!(
            "{:<12} {:>22} {:>22} {:>7.1}x",
            ds.name,
            cells[0],
            cells[1],
            secs[1] / secs[0].max(1e-9)
        );
        rows.push(
            Json::obj()
                .field("dataset", ds.name.as_str())
                .field("fast_column_s", secs[0])
                .field("implicit_row_s", secs[1]),
        );
    }
    bs::write_json("table4.json", &Json::obj().field("rows", rows));
}
