//! Table 3: Dory vs DoryNS vs the Ripser-like baseline — time and peak
//! memory, 1 vs 4 threads; plus the Hi-C rows only Dory can process.
//!
//!     cargo bench --bench table3_dory_vs_ripser [-- --full]

use dory::baselines::ripser_like;
use dory::bench_support as bs;
use dory::geometry::MetricData;
use dory::hic::{self, Condition, HiCParams};
use dory::homology::EngineOptions;
use dory::util::json::Json;
use dory::util::memtrack;

fn main() {
    let scale = bs::parse_scale();
    let mut rows = Json::arr();
    println!("== Table 3: (time, peak heap) per engine ==");
    println!(
        "{:<12} {:>22} {:>22} {:>22} {:>22} {:>22}",
        "dataset", "ripser-like", "dory 4thds", "dory 1thd", "doryNS 4thds", "doryNS 1thd"
    );

    // Ripser matrix budget mirrors the paper's practical limits.
    let budget = 8usize << 30;
    let mut datasets: Vec<(String, MetricData, f64, usize)> = bs::suite(scale)
        .into_iter()
        .map(|d| (d.name, d.data, d.tau, d.max_dim))
        .collect();
    let bins = bs::hic_bins(scale);
    for cond in [Condition::Control, Condition::Auxin] {
        let p = HiCParams {
            n_bins: bins,
            ..Default::default()
        };
        let name = match cond {
            Condition::Control => "HiC(control)",
            Condition::Auxin => "HiC(auxin)",
        };
        datasets.push((
            name.into(),
            MetricData::Sparse(hic::generate(&p, cond)),
            p.tau_max,
            2,
        ));
    }

    for (name, data, tau, max_dim) in &datasets {
        // Baseline first (its PD cross-checks the engines).
        memtrack::reset_peak();
        let t0 = std::time::Instant::now();
        let is_hic = name.starts_with("HiC");
        let baseline = if is_hic {
            // Faithful to the paper: combinatorial indexing + dense matrix
            // does not get through the Hi-C sets (overflow / 2-hour stop).
            Err(ripser_like::RipserError::MatrixTooLarge {
                bytes: data.n().saturating_mul(data.n()).saturating_mul(4),
            })
        } else {
            ripser_like::compute_ph(data, *tau, *max_dim, budget)
        };
        let base_cell = match &baseline {
            Ok(_) => bs::cell(t0.elapsed().as_secs_f64(), memtrack::section_peak_bytes()),
            Err(_) => "NA".to_string(),
        };

        let mut cells = vec![base_cell];
        let mut row = Json::obj().field("dataset", name.as_str());
        for (label, threads, dense) in [
            ("dory4", 4usize, false),
            ("dory1", 1, false),
            ("doryNS4", 4, true),
            ("doryNS1", 1, true),
        ] {
            // DoryNS on sparse million-bin data: the paper's own advice is
            // Dory; NS pays O(n²). Skip when the dense table would be huge.
            let dense_bytes = data.n().saturating_mul(data.n()) / 2 * 4;
            if dense && dense_bytes > budget {
                cells.push("NA".into());
                row = row.field(label, "NA");
                continue;
            }
            let opts = EngineOptions {
                max_dim: *max_dim,
                threads,
                dense_lookup: dense,
                ..Default::default()
            };
            let m = bs::run_engine(data, *tau, &opts);
            if let Ok(b) = &baseline {
                assert!(
                    m.result.diagram.multiset_eq(b, 2e-4),
                    "{name}/{label}: engine disagrees with baseline\n{}",
                    m.result.diagram.diff_summary(b)
                );
            }
            cells.push(bs::cell(m.seconds, m.peak_bytes));
            row = row.field(
                label,
                Json::obj()
                    .field("seconds", m.seconds)
                    .field("peak_bytes", m.peak_bytes),
            );
        }
        println!(
            "{:<12} {:>22} {:>22} {:>22} {:>22} {:>22}",
            name, cells[0], cells[1], cells[2], cells[3], cells[4]
        );
        rows.push(row);
    }
    bs::write_json("table3.json", &Json::obj().field("rows", rows));
    println!("\npaper shape check: dory << ripser-like memory on sparse");
    println!("filtrations (torus4); ripser-like NA on Hi-C; doryNS trades");
    println!("memory for speed on non-sparse d=2 sets.");
}
