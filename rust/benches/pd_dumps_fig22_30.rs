//! Figures 4 & 22–30: persistence diagrams for every benchmark dataset
//! (and the Hi-C pair), dumped as CSV under `target/bench_out/pd/` and
//! summarized as ASCII scatter plots.
//!
//!     cargo bench --bench pd_dumps_fig22_30 [-- --full]

use dory::bench_support as bs;
use dory::geometry::MetricData;
use dory::hic::{self, Condition, HiCParams};
use dory::homology::EngineOptions;
use dory::io;

fn ascii_pd(points: &[dory::homology::diagram::Point], tau: f64) {
    // 20x40 scatter of (birth, death), essential classes on the top row.
    const H: usize = 14;
    const W: usize = 44;
    let mut grid = vec![[' '; W]; H];
    let lim = if tau.is_finite() {
        tau
    } else {
        points
            .iter()
            .filter(|p| !p.is_essential())
            .map(|p| p.death)
            .fold(1.0, f64::max)
    };
    for p in points {
        let x = ((p.birth / lim) * (W - 1) as f64).min((W - 1) as f64) as usize;
        if p.is_essential() {
            grid[0][x] = '^';
        } else {
            let y = ((p.death / lim) * (H - 1) as f64).min((H - 1) as f64) as usize;
            let row = H - 1 - y;
            grid[row][x] = if grid[row][x] == '*' { '#' } else { '*' };
        }
    }
    for row in &grid {
        println!("  |{}|", row.iter().collect::<String>());
    }
    println!("  (x birth -> {lim:.2}, y death; ^ = essential)");
}

fn main() {
    let scale = bs::parse_scale();
    let dir = bs::out_dir().join("pd");
    std::fs::create_dir_all(&dir).unwrap();

    let mut jobs: Vec<(String, MetricData, f64, usize)> = bs::suite(scale)
        .into_iter()
        .map(|d| (d.name, d.data, d.tau, d.max_dim))
        .collect();
    // Fig 4: the intro's multi-scale demo.
    jobs.insert(
        0,
        (
            "fig4_demo".into(),
            dory::datasets::multi_scale_demo(600, 7),
            8.0,
            1,
        ),
    );
    // Figs 29-30: Hi-C PDs.
    let p = HiCParams {
        n_bins: bs::hic_bins(scale).min(12_000),
        ..Default::default()
    };
    for cond in [Condition::Control, Condition::Auxin] {
        let name = format!("hic_{cond:?}").to_lowercase();
        jobs.push((
            name,
            MetricData::Sparse(hic::generate(&p, cond)),
            p.tau_max,
            2,
        ));
    }

    for (name, data, tau, max_dim) in jobs {
        let opts = EngineOptions {
            max_dim,
            threads: 4,
            ..Default::default()
        };
        let m = bs::run_engine(&data, tau, &opts);
        let path = dir.join(format!("{}.csv", name.replace(['(', ')'], "_")));
        io::write_diagram_csv(&path, &m.result.diagram).unwrap();
        println!(
            "\n== {name}: PD written to {path:?} ({:.2}s) ==",
            m.seconds
        );
        for dim in 1..=max_dim {
            let pts = m.result.diagram.points(dim);
            if pts.is_empty() {
                continue;
            }
            println!("H{dim} ({} classes):", pts.len());
            ascii_pd(pts, tau);
        }
    }
}
