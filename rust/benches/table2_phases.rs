//! Tables 1 & 2: dataset inventory and Dory's per-phase timings.
//!
//!     cargo bench --bench table2_phases [-- --full]
//!
//! Table 1 columns: n, τ_m, n_e, d, N (total simplices).
//! Table 2 columns: create F1, create neighborhoods, H0, H1*, H2*
//! (Dory, 4 threads, as in the paper).

use dory::bench_support as bs;
use dory::filtration::{EdgeFiltration, Neighborhoods};
use dory::homology::{engine::count_simplices, EngineOptions};
use dory::util::json::Json;

fn main() {
    let scale = bs::parse_scale();
    let suite = bs::suite(scale);
    println!("== Table 1: data sets ({scale:?} scale) ==");
    println!(
        "{:<12} {:>8} {:>8} {:>10} {:>3} {:>14}",
        "dataset", "n", "tau_m", "n_e", "d", "N"
    );
    let mut t1 = Json::arr();
    for ds in &suite {
        let f = EdgeFiltration::build(&ds.data, ds.tau);
        let nb = Neighborhoods::build(&f, false);
        let n_simplices = count_simplices(&f, &nb, ds.max_dim);
        println!(
            "{:<12} {:>8} {:>8} {:>10} {:>3} {:>14}",
            ds.name,
            ds.data.n(),
            if ds.tau.is_finite() {
                format!("{}", ds.tau)
            } else {
                "inf".into()
            },
            f.n_edges(),
            ds.max_dim,
            n_simplices
        );
        t1.push(
            Json::obj()
                .field("dataset", ds.name.as_str())
                .field("n", ds.data.n())
                .field("tau", ds.tau)
                .field("n_e", f.n_edges())
                .field("d", ds.max_dim)
                .field("N", n_simplices as f64),
        );
    }

    println!("\n== Table 2: Dory phase timings (seconds, 4 threads) ==");
    println!(
        "{:<12} {:>10} {:>12} {:>8} {:>8} {:>8} {:>7} {:>10}",
        "dataset", "create F1", "create N,E", "H0", "H1*", "H2*", "skip%", "max RSS"
    );
    let mut t2 = Json::arr();
    let mut sched_rows = Vec::new();
    let mut frontend_rows: Vec<(String, dory::filtration::FiltrationStats)> = Vec::new();
    for ds in &suite {
        let opts = EngineOptions {
            max_dim: ds.max_dim,
            threads: 4,
            ..Default::default()
        };
        let m = bs::run_engine(&ds.data, ds.tau, &opts);
        let t = &m.result.timings;
        let g = |name: &str| t.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0);
        // Per-phase max-RSS high-water marks (sampled at each phase
        // boundary) — the headline memory claim, per dataset.
        let stats = &m.result.stats;
        let candidates = stats.h1.columns
            + stats.h1.shortcut_pairs
            + stats.h2.columns
            + stats.h2.shortcut_pairs;
        let skipped = stats.h1.shortcut_pairs + stats.h2.shortcut_pairs;
        let skip_pct = if candidates > 0 {
            skipped as f64 / candidates as f64 * 100.0
        } else {
            0.0
        };
        let run_rss = t.phases().iter().map(|p| p.max_rss_end).max().unwrap_or(0);
        println!(
            "{:<12} {:>10.3} {:>12.3} {:>8.3} {:>8.3} {:>8.3} {:>6.1}% {:>10}",
            ds.name,
            g("F1"),
            g("neighborhoods"),
            g("H0"),
            g("H1*"),
            g("H2*"),
            skip_pct,
            dory::util::memtrack::fmt_bytes(run_rss),
        );
        let sched = m.result.stats.sched_total();
        sched_rows.push((ds.name.clone(), sched));
        frontend_rows.push((ds.name.clone(), m.result.stats.filtration));
        let mut phase_rss = Json::obj();
        for p in t.phases() {
            phase_rss = phase_rss.field(&p.name, p.max_rss_end);
        }
        let fs = &m.result.stats.filtration;
        t2.push(
            Json::obj()
                .field("dataset", ds.name.as_str())
                .field("f1", g("F1"))
                .field("dist_kernel", fs.dist_kernel)
                .field("f1_dist", fs.dist_ns as f64 * 1e-9)
                .field("f1_sort", fs.sort_ns as f64 * 1e-9)
                .field("f1_nb", fs.nb_ns as f64 * 1e-9)
                .field("f1_tiles", fs.tiles as f64)
                .field("f1_kept", fs.edges_kept as f64)
                .field("f1_pruned", fs.edges_pruned as f64)
                .field("neighborhoods", g("neighborhoods"))
                .field("h0", g("H0"))
                .field("h1", g("H1*"))
                .field("h2", g("H2*"))
                .field("total", m.seconds)
                .field("max_rss_bytes", run_rss)
                .field("phase_max_rss_bytes", phase_rss)
                .field("h1_shortcut_pairs", stats.h1.shortcut_pairs)
                .field("h1_skip_rate", stats.h1.skip_rate())
                .field("h2_shortcut_pairs", stats.h2.shortcut_pairs)
                .field("h2_skip_rate", stats.h2.skip_rate())
                .field("sched_h1", m.result.stats.h1_sched.to_json())
                .field("sched_h2", m.result.stats.h2_sched.to_json()),
        );
    }

    // The pipelined-scheduler report: how much serial-commit time was
    // hidden under a parallel push (the seed's hard barrier hid none),
    // how much residual barrier idle remains, and the enumeration span
    // (shards enumerated on the pool; busy = worker time in shard
    // fills, blocked = the part the three-stage pipeline failed to
    // hide under pushes/commits).
    println!("\n== Pipelined scheduler (4 threads, H1*+H2* combined) ==");
    println!(
        "{:<12} {:>8} {:>12} {:>9} {:>10} {:>10} {:>10} {:>6} {:>7} {:>9} {:>9} {:>9}",
        "dataset",
        "batches",
        "batch range",
        "steals",
        "serial s",
        "overlap s",
        "idle s",
        "util",
        "shards",
        "enum s",
        "blocked s",
        "skipped"
    );
    for (name, s) in &sched_rows {
        println!(
            "{:<12} {:>8} {:>6}..{:<5} {:>9} {:>10.3} {:>10.3} {:>10.3} {:>5.0}% {:>7} {:>9.3} {:>9.3} {:>9}",
            name,
            s.batches,
            s.min_batch,
            s.max_batch,
            s.steals,
            s.serial_ns as f64 * 1e-9,
            s.overlap_ns as f64 * 1e-9,
            s.barrier_wait_ns as f64 * 1e-9,
            s.utilization() * 100.0,
            s.enum_shards,
            s.enum_busy_ns as f64 * 1e-9,
            s.enum_block_ns as f64 * 1e-9,
            s.shortcut_columns,
        );
    }

    // The pooled front-end breakdown: distance tiles, sort chunks and
    // CSR fill all execute on the worker pool; `pruned` counts edges
    // dropped by the enclosing-radius truncation (nonzero only on the
    // infinite-tau sets).
    println!("\n== Front-end (pool-tiled F1, 4 threads) ==");
    println!(
        "{:<12} {:>7} {:>9} {:>9} {:>9} {:>7} {:>7} {:>12} {:>10}",
        "dataset", "kernel", "dist s", "sort s", "nbhd s", "tiles", "chunks", "kept", "pruned"
    );
    for (name, fs) in &frontend_rows {
        println!(
            "{:<12} {:>7} {:>9.3} {:>9.3} {:>9.3} {:>7} {:>7} {:>12} {:>10}",
            name,
            if fs.dist_kernel.is_empty() { "-" } else { fs.dist_kernel },
            fs.dist_ns as f64 * 1e-9,
            fs.sort_ns as f64 * 1e-9,
            fs.nb_ns as f64 * 1e-9,
            fs.tiles,
            fs.sort_chunks + fs.nb_chunks,
            fs.edges_kept,
            fs.edges_pruned,
        );
    }

    bs::write_json(
        "table1_table2.json",
        &Json::obj().field("table1", t1).field("table2", t2),
    );
    println!("\npaper shape check: H2* dominates where d=2; F1 is a large");
    println!("fraction only on the dense full-filtration sets (dragon).");
    println!("scheduler shape check: overlap ≈ serial (commit hidden under");
    println!("the next push) and idle ≪ serial on the reduction-bound sets;");
    println!("enumeration shards > 0 everywhere (H1*/H2* columns are");
    println!("enumerated on the pool) with blocked ≪ enum busy; skip% high");
    println!("on the d=2 sets (most columns are apparent pairs resolved");
    println!("in-shard, never entering a BucketTable).");
}
