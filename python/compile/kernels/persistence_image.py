"""Layer 1: Pallas persistence-image kernel.

Persistence images (Adams et al.) are the standard PD vectorization the
paper's Discussion points at for downstream ML (PI-Net). Input is a
``(K, 3)`` array of ``(birth, persistence, weight)`` rows (weight 0 =
padding); output a ``(G, G)`` Gaussian raster over ``[0, span]^2``.

Decomposition: the grid axis is tiled — each Pallas cell owns ``(TG, G)``
output rows and loops over the *whole* pair block held in VMEM
(``K*3*4`` bytes; K<=1024 is 12 KiB). Work per cell is VPU-style
broadcast arithmetic; there is no MXU term, so the tile size is chosen
purely to keep ``TG*G + K*3`` floats in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_GRID = 32
SIGMA_FRAC = 0.05  # bandwidth = SIGMA_FRAC * span


def _pimage_tile_kernel(pairs_ref, span_ref, o_ref, *, grid: int, tile: int):
    pid = pl.program_id(0)
    pairs = pairs_ref[...]  # (K, 3)
    span = span_ref[0, 0]
    births = pairs[:, 0]  # (K,)
    pers = pairs[:, 1]
    weight = pairs[:, 2]
    sigma = SIGMA_FRAC * span
    inv2s2 = 1.0 / (2.0 * sigma * sigma + 1e-30)
    cell = span / grid
    # Pixel centres: x = birth axis (columns), y = persistence axis (rows).
    rows = (pid * tile + jax.lax.broadcasted_iota(jnp.float32, (tile, 1), 0) + 0.5) * cell
    cols = (jax.lax.broadcasted_iota(jnp.float32, (1, grid), 1) + 0.5) * cell
    # Accumulate over pairs: (tile, grid, K) would blow VMEM for big K;
    # fori_loop keeps it at (tile, grid) per step.
    def body(k, acc):
        dx = cols - births[k]  # (1, G)
        dy = rows - pers[k]  # (TG, 1)
        g = jnp.exp(-(dx * dx + dy * dy) * inv2s2)
        return acc + weight[k] * g

    acc = jax.lax.fori_loop(0, pairs.shape[0], body, jnp.zeros((tile, grid), jnp.float32))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("grid", "tile"))
def persistence_image(pairs, span, grid: int = DEFAULT_GRID, tile: int = 8):
    """Rasterize ``pairs`` (K, 3) into a (grid, grid) image over [0, span]²."""
    if grid % tile != 0:
        raise ValueError(f"grid={grid} must be a multiple of tile={tile}")
    k = pairs.shape[0]
    span_arr = jnp.asarray(span, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_pimage_tile_kernel, grid=grid, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(grid // tile,),
        in_specs=[
            pl.BlockSpec((k, 3), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, grid), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, grid), jnp.float32),
        interpret=True,
    )(pairs.astype(jnp.float32), span_arr)
