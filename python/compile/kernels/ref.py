"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest + hypothesis assert allclose against these)."""

import jax.numpy as jnp

from .persistence_image import SIGMA_FRAC


def pairwise_distance_ref(points):
    """(n, d) -> (n, n) Euclidean distances, straightforward broadcast."""
    x = points.astype(jnp.float32)
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def persistence_image_ref(pairs, span, grid: int):
    """(K, 3) (birth, persistence, weight) -> (grid, grid) raster."""
    pairs = pairs.astype(jnp.float32)
    span = jnp.float32(span)
    cell = span / grid
    ys = (jnp.arange(grid, dtype=jnp.float32) + 0.5) * cell  # rows: persistence
    xs = (jnp.arange(grid, dtype=jnp.float32) + 0.5) * cell  # cols: birth
    sigma = SIGMA_FRAC * span
    inv2s2 = 1.0 / (2.0 * sigma * sigma + 1e-30)
    dx = xs[None, None, :] - pairs[:, 0][:, None, None]  # (K,1,G)
    dy = ys[None, :, None] - pairs[:, 1][:, None, None]  # (K,G,1)
    g = jnp.exp(-(dx * dx + dy * dy) * inv2s2)  # (K,G,G)
    return jnp.sum(pairs[:, 2][:, None, None] * g, axis=0)
