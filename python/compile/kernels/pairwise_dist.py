"""Layer 1: tiled Pallas pairwise-distance kernel.

The paper's pipeline turns a point cloud into the sparse edge filtration;
the dense compute hot-spot is the pairwise distance matrix. On TPU the
natural decomposition is the classic blocked Gram-matrix schedule:

* grid cell (i, j) owns a ``(TM, TN)`` output tile;
* the ``x`` tile ``(TM, D)`` and ``y`` tile ``(TN, D)`` are staged through
  VMEM by BlockSpec (the HBM <-> VMEM schedule a CUDA version would write
  with threadblocks);
* the cross term ``x @ y.T`` is an MXU-shaped matmul
  (``preferred_element_type=float32`` keeps the systolic-array accumulate
  in f32); row/col norms ride on the VPU.

VMEM footprint per cell: ``(TM*D + TN*D + TM*TN) * 4`` bytes — 128x128
tiles with D<=16 stay under 100 KiB, far inside the ~16 MiB VMEM budget
(see DESIGN.md §Hardware-Adaptation and §Perf).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated*, not measured, in this
image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _dist_tile_kernel(x_ref, y_ref, o_ref):
    """One (TM, TN) tile: sqrt(max(|x|^2 + |y|^2 - 2 x.y, 0))."""
    x = x_ref[...].astype(jnp.float32)  # (TM, D)
    y = y_ref[...].astype(jnp.float32)  # (TN, D)
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (TM, 1)  VPU
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, TN)  VPU
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    sq = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.sqrt(sq)


@functools.partial(jax.jit, static_argnames=("tile",))
def pairwise_distance(points, tile: int = DEFAULT_TILE):
    """Full symmetric distance matrix of ``points`` (n, d), n % tile == 0.

    Returns an (n, n) float32 matrix. The caller (Layer 2 / the Rust
    runtime) pads n up to a tile multiple; padding points sit at a huge
    coordinate so their rows/columns exceed any filtration threshold.
    """
    n, d = points.shape
    if n % tile != 0:
        raise ValueError(f"n={n} must be a multiple of tile={tile}")
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _dist_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(points, points)


def vmem_bytes_per_cell(tile: int, d: int) -> int:
    """VMEM footprint estimate for one grid cell (see DESIGN.md §Perf)."""
    return 4 * (tile * d + tile * d + tile * tile)


def mxu_flops_per_cell(tile: int, d: int) -> int:
    """MXU work per grid cell: the 2*TM*TN*D cross-term flops."""
    return 2 * tile * tile * d
