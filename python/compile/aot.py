"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text, NOT ``lowered.compile()`` / serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import sys

from jax._src.lib import xla_client as xc

from . import model

# (rows, cols) menu for the distance kernel. cols=16 covers every bench
# dataset dim (<= 9) — unused coordinates are zero-padded and cancel.
DIST_SHAPES = [(256, 16), (1024, 16), (2048, 16)]
# (max pairs, grid) menu for the persistence-image kernel.
PIMAGE_SHAPES = [(256, 32), (1024, 64)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="smallest shapes only")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dist": [], "pimage": []}
    dist_shapes = DIST_SHAPES[:1] if args.quick else DIST_SHAPES
    pimage_shapes = PIMAGE_SHAPES[:1] if args.quick else PIMAGE_SHAPES

    for n, d in dist_shapes:
        text = to_hlo_text(model.lower_distance(n, d))
        path = os.path.join(args.out_dir, f"dist_{n}x{d}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["dist"].append({"rows": n, "cols": d, "bytes": len(text)})
        print(f"wrote {path} ({len(text)} chars)")

    for k, g in pimage_shapes:
        text = to_hlo_text(model.lower_pimage(k, g))
        path = os.path.join(args.out_dir, f"pimage_{k}x{g}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["pimage"].append({"pairs": k, "grid": g, "bytes": len(text)})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
