"""Layer 2: the JAX compute graph wrapping the Pallas kernels.

Two AOT entry points, lowered by ``aot.py`` to HLO text and executed from
the Rust runtime through PJRT:

* ``distance_matrix`` — full pairwise distances (calls the L1 tiled
  kernel). The Rust side pads the point count to the artifact's row count
  (padding points parked far away) and slices the real block out.
* ``pimage_model`` — persistence-image rasterization of a PD.

Nothing here runs at request time; ``make artifacts`` is the only Python
invocation in the lifecycle.
"""

import jax
import jax.numpy as jnp

from .kernels.pairwise_dist import DEFAULT_TILE, pairwise_distance
from .kernels.persistence_image import persistence_image


def distance_matrix(points, tile: int = DEFAULT_TILE):
    """(n, d) -> (n, n) float32; n must be a multiple of ``tile``."""
    return pairwise_distance(points.astype(jnp.float32), tile=tile)


def distance_matrix_padded(points, tile: int = DEFAULT_TILE, pad_value: float = 1.0e7):
    """Convenience for tests: pad any (n, d) up to a tile multiple, compute,
    slice back. The Rust runtime does this padding natively."""
    n, d = points.shape
    m = -(-n // tile) * tile
    padded = jnp.full((m, d), pad_value, jnp.float32).at[:n].set(points.astype(jnp.float32))
    return distance_matrix(padded, tile=tile)[:n, :n]


def pimage_model(pairs, span, grid: int):
    """(K, 3), scalar span -> (grid, grid) float32."""
    return persistence_image(pairs, span, grid=grid)


def lower_distance(n: int, d: int, tile: int = DEFAULT_TILE):
    """jax.jit lowering for the (n, d) distance artifact."""
    spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return jax.jit(lambda p: (distance_matrix(p, tile=tile),)).lower(spec)


def lower_pimage(k: int, grid: int):
    """jax.jit lowering for the (k pairs, grid) persistence-image artifact."""
    pairs = jax.ShapeDtypeStruct((k, 3), jnp.float32)
    span = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(lambda p, s: (pimage_model(p, s, grid=grid),)).lower(pairs, span)
