"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.pairwise_dist import (
    mxu_flops_per_cell,
    pairwise_distance,
    vmem_bytes_per_cell,
)
from compile.kernels.persistence_image import persistence_image
from compile.kernels import ref


# ---------- pairwise distance ------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    d=st.integers(1, 9),
    tile=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_matches_ref(n_tiles, d, tile, seed):
    rng = np.random.default_rng(seed)
    n = n_tiles * tile
    pts = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = np.asarray(pairwise_distance(pts, tile=tile))
    want = np.asarray(ref.pairwise_distance_ref(pts))
    # The Gram formulation |x|²+|y|²-2x·y loses ~eps·scale² absolutely in
    # the *squared* distance (catastrophic cancellation for near-duplicate
    # points); the distance error is bounded by sqrt of that.
    scale2 = float(np.max(np.sum(np.asarray(pts) ** 2, axis=1)))
    sq_atol = 64 * np.finfo(np.float32).eps * (1.0 + scale2)
    np.testing.assert_allclose(got**2, want**2, atol=sq_atol, rtol=1e-4)
    np.testing.assert_allclose(got, want, atol=np.sqrt(sq_atol), rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_pairwise_metric_properties(seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
    m = np.asarray(pairwise_distance(pts, tile=8))
    # Diagonal is sqrt(cancellation residue): ~sqrt(eps)·scale, not 0.
    assert np.allclose(np.diag(m), 0.0, atol=5e-3)
    assert np.allclose(m, m.T, atol=1e-5)
    assert (m >= 0).all()


def test_pairwise_exact_small():
    pts = jnp.asarray([[0.0, 0.0], [3.0, 4.0]] * 4, jnp.float32)
    m = np.asarray(pairwise_distance(pts, tile=8))
    assert abs(m[0, 1] - 5.0) < 1e-5


def test_pairwise_rejects_unaligned():
    with pytest.raises(ValueError):
        pairwise_distance(jnp.zeros((100, 3), jnp.float32), tile=128)


def test_padding_helper_matches_ref():
    from compile.model import distance_matrix_padded

    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.normal(size=(37, 5)), jnp.float32)
    got = distance_matrix_padded(pts, tile=16)
    want = ref.pairwise_distance_ref(pts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_f64_inputs_are_cast():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.normal(size=(16, 3)))  # f64 -> cast inside
    got = pairwise_distance(pts.astype(jnp.float32), tile=8)
    assert got.dtype == jnp.float32


def test_vmem_estimate_within_budget():
    # DESIGN.md §Perf: the production tile must fit VMEM comfortably.
    assert vmem_bytes_per_cell(128, 16) < 128 * 1024
    assert mxu_flops_per_cell(128, 16) == 2 * 128 * 128 * 16


# ---------- persistence image -------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 40),
    grid=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pimage_matches_ref(k, grid, seed):
    rng = np.random.default_rng(seed)
    span = 2.0
    pairs = np.zeros((k, 3), np.float32)
    pairs[:, 0] = rng.uniform(0, span, k)  # births
    pairs[:, 1] = rng.uniform(0, span, k)  # persistences
    pairs[:, 2] = rng.uniform(0, 2, k)  # weights
    got = persistence_image(jnp.asarray(pairs), span, grid=grid, tile=4)
    want = ref.persistence_image_ref(jnp.asarray(pairs), span, grid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_pimage_zero_weights_are_invisible():
    pairs = np.array([[0.5, 0.5, 1.0], [1.5, 1.5, 0.0]], np.float32)
    img = np.asarray(persistence_image(jnp.asarray(pairs), 2.0, grid=16, tile=4))
    only = np.asarray(
        persistence_image(jnp.asarray(pairs[:1]), 2.0, grid=16, tile=4)
    )
    # Padding rows (weight 0) must contribute nothing.
    np.testing.assert_allclose(img, only, atol=1e-6)


def test_pimage_peak_near_the_point():
    pairs = np.array([[1.0, 1.0, 1.0]], np.float32)
    img = np.asarray(persistence_image(jnp.asarray(pairs), 2.0, grid=32, tile=8))
    r, c = np.unravel_index(np.argmax(img), img.shape)
    # Point (birth=1, pers=1) is the grid centre.
    assert abs(r - 15.5) <= 1.0 and abs(c - 15.5) <= 1.0
