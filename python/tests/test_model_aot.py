"""L2 model shapes + AOT HLO-text emission."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import aot, model


def test_lower_distance_emits_hlo_text():
    lowered = model.lower_distance(256, 16, tile=128)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[256,16]" in text, text[:400]
    assert "f32[256,256]" in text


def test_lower_pimage_emits_hlo_text():
    lowered = model.lower_pimage(256, 32)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[256,3]" in text
    assert "f32[32,32]" in text


def test_distance_model_agrees_with_kernel_padding():
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.normal(size=(64, 9)), jnp.float32)
    m = model.distance_matrix(pts, tile=16)
    assert m.shape == (64, 64)
    # Spot-check one entry against scalar math.
    want = float(jnp.sqrt(jnp.sum((pts[3] - pts[41]) ** 2)))
    assert abs(float(m[3, 41]) - want) < 1e-4


def test_far_padding_exceeds_thresholds():
    # The Rust runtime pads with 1e7-coordinate points; their distances to
    # real points must dwarf any realistic tau.
    pts = np.zeros((16, 4), np.float32)
    pts[8:] = 1.0e7
    m = np.asarray(model.distance_matrix(jnp.asarray(pts), tile=8))
    assert (m[:8, 8:] > 1.0e6).all()
    assert np.allclose(m[:8, :8], 0.0, atol=1e-3)


def test_aot_main_writes_artifacts(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--quick"]
    try:
        assert aot.main() == 0
    finally:
        sys.argv = argv
    names = sorted(p.name for p in tmp_path.iterdir())
    assert "dist_256x16.hlo.txt" in names
    assert "pimage_256x32.hlo.txt" in names
    assert "manifest.json" in names


def test_lowering_is_shape_stable():
    # Same shape twice -> identical HLO text (AOT determinism).
    a = aot.to_hlo_text(model.lower_distance(256, 16))
    b = aot.to_hlo_text(model.lower_distance(256, 16))
    assert a == b
