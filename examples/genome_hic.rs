//! Topology of the (synthetic) genome: control vs auxin (paper §6).
//!
//!     cargo run --release --example genome_hic [-- --bins 20000]
//!
//! Generates the Hi-C substrate in both conditions, computes PH up to H2
//! on the sparse filtrations, and prints Figure 21's percent-change-in-
//! Betti curves plus the loop/void summaries. The qualitative claim to
//! reproduce: auxin (cohesin degradation) eliminates most loops (H1) and
//! most voids (H2) are never born.

use dory::error::DoryError;
use dory::features::{FeatureSpec, FeatureValue};
use dory::geometry::MetricData;
use dory::hic::{self, Condition, HiCParams};
use dory::homology::{EngineOptions, PhRequest, Session};
use dory::util::memtrack;

/// Loops below this persistence are contact-noise, not called loops
/// (the same threshold the Fig 21 "significant" H1 count uses).
const LOOP_MIN_PERSISTENCE: f64 = 40.0;

fn main() -> Result<(), DoryError> {
    let mut bins = 20_000usize;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--bins") {
        bins = args[i + 1].parse().expect("--bins <int>");
    }
    let params = HiCParams {
        n_bins: bins,
        ..Default::default()
    };
    // One session — both conditions share the engine's worker pool
    // (handles are per-dataset; no pool is torn down in between).
    let session = Session::new(EngineOptions {
        max_dim: 2,
        threads: 4,
        ..Default::default()
    });

    let mut results = Vec::new();
    for cond in [Condition::Control, Condition::Auxin] {
        let sd = hic::generate(&params, cond);
        let ne = sd.entries.len();
        memtrack::reset_peak();
        let t0 = std::time::Instant::now();
        let handle = session.ingest(&MetricData::Sparse(sd), params.tau_max)?;
        // The served query also carries the loop-calling feature: one
        // tightened representative per significant H1 class, anchored on
        // its birth edge — for Hi-C, the two genomic anchor bins.
        let resp = session.query(
            &handle,
            &PhRequest {
                tau: params.tau_max,
                features: vec![FeatureSpec::Representatives {
                    min_persistence: LOOP_MIN_PERSISTENCE,
                }],
                ..Default::default()
            },
        )?;
        let r = resp.result;
        println!(
            "{cond:?}: n={bins} n_e={ne} | {:.2}s, peak heap {} | {}",
            t0.elapsed().as_secs_f64(),
            memtrack::fmt_bytes(memtrack::section_peak_bytes()),
            r.timings.summary()
        );
        println!(
            "  H1: {} classes ({} significant) | H2: {} classes ({} significant)",
            r.diagram.points(1).len(),
            r.diagram.significant(1, 40.0).len(),
            r.diagram.points(2).len(),
            r.diagram.significant(2, 20.0).len(),
        );
        // The loop list: anchor bin pairs + persistence, strongest first.
        let fo = resp.features.as_ref().expect("representatives requested");
        if let Some(FeatureValue::Representatives(cycles)) =
            fo.items.first().map(|i| &i.value)
        {
            let mut ranked: Vec<_> = cycles.iter().collect();
            ranked.sort_by(|a, b| b.persistence().total_cmp(&a.persistence()));
            println!(
                "  loop list ({} loops with persistence > {LOOP_MIN_PERSISTENCE}):",
                ranked.len()
            );
            for c in ranked.iter().take(10) {
                println!(
                    "    loop anchor=({:>6},{:>6}) birth={:>7.1} pers={:>7.1} \
                     perimeter={:>8.1} span={:>4} bins",
                    c.anchor.0,
                    c.anchor.1,
                    c.birth,
                    c.persistence(),
                    c.perimeter,
                    c.vertices.len(),
                );
            }
            if ranked.len() > 10 {
                println!("    ... {} more", ranked.len() - 10);
            }
        }
        results.push(r);
    }
    let (ctrl, aux) = (&results[0], &results[1]);

    // Figure 21: percent change in β1 / β2 per threshold.
    println!("\nFig 21 — percent change upon auxin ((auxin-control)/control*100):");
    println!("{:>9} {:>10} {:>10} {:>9} {:>9}", "tau", "b1_ctrl", "b1_auxin", "d_b1%", "d_b2%");
    let ts: Vec<f64> = (1..=8).map(|k| k as f64 * 50.0).collect();
    for &t in &ts {
        let (b1c, b1a) = (ctrl.diagram.betti_at(1, t), aux.diagram.betti_at(1, t));
        let (b2c, b2a) = (ctrl.diagram.betti_at(2, t), aux.diagram.betti_at(2, t));
        let pct = |c: usize, a: usize| {
            if c == 0 {
                0.0
            } else {
                (a as f64 - c as f64) / c as f64 * 100.0
            }
        };
        println!(
            "{t:>9.0} {b1c:>10} {b1a:>10} {:>8.1}% {:>8.1}%",
            pct(b1c, b1a),
            pct(b2c, b2a)
        );
    }
    println!("\nPaper's qualitative result: strong reduction in loops at all");
    println!("thresholds and voids mostly not born under auxin — corroborated");
    println!("if the d_b1%/d_b2% columns are strongly negative.");
    Ok(())
}
