//! Dory vs DoryNS vs Ripser-like vs Gudhi-like on the Clifford torus —
//! the Table 3/5 story at example scale.
//!
//!     cargo run --release --example torus_vs_baselines [-- --n 4000]
//!
//! Shows the paper's core claim: on sparse filtrations Dory's memory is
//! bounded by O(n_e) structures while combinatorial-indexing and explicit
//! approaches pay O(n²) / O(#simplices).

use dory::baselines::{gudhi_like, ripser_like};
use dory::datasets;
use dory::homology::{Algorithm, EngineOptions, PhRequest, Session};
use dory::util::memtrack;

fn main() {
    let mut n = 4000usize;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--n") {
        n = args[i + 1].parse().expect("--n <int>");
    }
    let tau = 0.4;
    let data = datasets::torus4(n, 42);
    println!("torus4: n={n}, tau={tau}, dim<=1\n");
    println!(
        "{:<28} {:>9} {:>12} {:>8} {:>10}",
        "engine", "time", "peak heap", "H1", "H1 ess"
    );

    let mut reference = None;
    for (name, threads, dense, algo) in [
        ("dory (4 thds)", 4usize, false, Algorithm::FastColumn),
        ("dory (1 thd)", 1, false, Algorithm::FastColumn),
        ("doryNS (4 thds)", 4, true, Algorithm::FastColumn),
        ("dory implicit-row (1 thd)", 1, false, Algorithm::ImplicitRow),
    ] {
        let opts = EngineOptions {
            max_dim: 1,
            threads,
            batch_size: 100,
            dense_lookup: dense,
            algorithm: algo,
            ..Default::default()
        };
        memtrack::reset_peak();
        let t0 = std::time::Instant::now();
        // Session per engine configuration (the ablation varies
        // handle-level knobs like dense_lookup, so each row ingests).
        let session = Session::new(opts);
        let h = session.ingest(&data, tau).expect("ingest");
        let r = session.query(&h, &PhRequest::at(tau)).expect("query").result;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{name:<28} {:>8.2}s {:>12} {:>8} {:>10}",
            dt,
            memtrack::fmt_bytes(memtrack::section_peak_bytes()),
            r.diagram.finite(1).len(),
            r.diagram.essential_count(1)
        );
        if let Some(ref d) = reference {
            assert!(r.diagram.multiset_eq(d, 1e-9), "engine mismatch: {name}");
        } else {
            reference = Some(r.diagram);
        }
    }

    // Ripser-like: dense O(n²) matrix + combinatorial indices.
    memtrack::reset_peak();
    let t0 = std::time::Instant::now();
    match ripser_like::compute_ph(&data, tau, 1, 8 << 30) {
        Ok(d) => {
            println!(
                "{:<28} {:>8.2}s {:>12} {:>8} {:>10}",
                "ripser-like",
                t0.elapsed().as_secs_f64(),
                memtrack::fmt_bytes(memtrack::section_peak_bytes()),
                d.finite(1).len(),
                d.essential_count(1)
            );
            assert!(
                d.multiset_eq(reference.as_ref().unwrap(), 2e-4),
                "baseline mismatch"
            );
        }
        Err(e) => println!("{:<28} NA ({e:?})", "ripser-like"),
    }

    // Gudhi-like: explicit simplex tree (skip when it would be huge).
    if n <= 6000 {
        memtrack::reset_peak();
        let t0 = std::time::Instant::now();
        let d = gudhi_like::compute_ph(&data, tau, 1);
        println!(
            "{:<28} {:>8.2}s {:>12} {:>8} {:>10}",
            "gudhi-like (simplex tree)",
            t0.elapsed().as_secs_f64(),
            memtrack::fmt_bytes(memtrack::section_peak_bytes()),
            d.finite(1).len(),
            d.essential_count(1)
        );
        assert!(
            d.multiset_eq(reference.as_ref().unwrap(), 1e-9),
            "gudhi-like mismatch"
        );
    } else {
        println!("{:<28} NA (explicit tree too large)", "gudhi-like");
    }
    println!("\nAll engines agree on the PD; compare the memory column.");
}
