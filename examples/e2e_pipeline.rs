//! End-to-end driver: every layer of the stack on a real small workload.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline
//!
//! Path exercised:
//!   L1/L2  Pallas pairwise-distance kernel, AOT-lowered to HLO text
//!   PJRT   Rust loads artifacts/*.hlo.txt and executes them (no Python)
//!   L3     Dory engine: H0 union-find → H1*/H2* fast implicit column
//!          reduction, serial–parallel over the thread pool
//!   L1/L2  Pallas persistence-image kernel on the resulting PD
//!   + the Ripser-like baseline on the same data (headline comparison)
//!
//! Reports the paper's headline metric shape: Dory's time and peak heap
//! vs the combinatorial-indexing baseline.

use dory::baselines::ripser_like;
use dory::datasets;
use dory::filtration::{EdgeFiltration, FiltrationStats};
use dory::geometry::MetricData;
use dory::homology::{EngineOptions, PhRequest, Session};
use dory::runtime::{default_artifact_dir, Runtime};
use dory::util::memtrack;
use dory::util::timer::PhaseTimer;

fn main() -> anyhow::Result<()> {
    let n = 1800usize; // fits the dist_2048x16 artifact
    let tau = 0.55;
    let data = datasets::torus4(n, 42);
    let pc = match &data {
        MetricData::Points(p) => p.clone(),
        _ => unreachable!(),
    };

    // ---- L3 session (owns the persistent pool) ----------------------------
    let opts = EngineOptions {
        max_dim: 2,
        threads: 4,
        batch_size: 100,
        ..Default::default()
    };
    let session = Session::new(opts);

    // ---- L1/L2 via PJRT: distance kernel ---------------------------------
    let rt = Runtime::load(&default_artifact_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let t0 = std::time::Instant::now();
    let mut fstats = FiltrationStats::default();
    let (f, source) = if rt.has_distance_kernel() {
        let raw = rt.distance_edges(&pc, tau)?;
        (
            EdgeFiltration::from_weighted_edges(n as u32, raw, tau),
            "pjrt-pallas",
        )
    } else {
        eprintln!("(no artifacts — run `make artifacts`; using native path)");
        (
            EdgeFiltration::build_pooled(
                &data,
                tau,
                session.engine().pool(),
                &session.engine().frontend_options(),
                &mut fstats,
            ),
            "native",
        )
    };
    let t_edges = t0.elapsed().as_secs_f64();
    println!(
        "edges: {} of C({n},2) via {source} in {t_edges:.2}s",
        f.n_edges()
    );

    // ---- L3: Dory engine over the session ---------------------------------
    memtrack::reset_peak();
    let t0 = std::time::Instant::now();
    let handle = session.ingest_filtration(f, PhaseTimer::new(), fstats, source)?;
    let r = session.query(&handle, &PhRequest::at(tau))?.result;
    let t_dory = t0.elapsed().as_secs_f64();
    let dory_peak = memtrack::section_peak_bytes();
    println!(
        "dory: {:.2}s, peak heap {} | {}",
        t_dory,
        memtrack::fmt_bytes(dory_peak),
        r.timings.summary()
    );
    for dim in 0..=2 {
        println!(
            "  H{dim}: {} finite, {} essential",
            r.diagram.finite(dim).len(),
            r.diagram.essential_count(dim)
        );
    }
    let loops = r.diagram.significant(1, 0.25);
    println!("  significant H1 classes (pers > 0.25): {}", loops.len());

    // ---- Baseline: ripser-like -------------------------------------------
    memtrack::reset_peak();
    let t0 = std::time::Instant::now();
    let base = ripser_like::compute_ph(&data, tau, 2, usize::MAX)
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let t_base = t0.elapsed().as_secs_f64();
    let base_peak = memtrack::section_peak_bytes();
    println!(
        "ripser-like baseline: {:.2}s, peak heap {}",
        t_base,
        memtrack::fmt_bytes(base_peak)
    );
    assert!(
        r.diagram.multiset_eq(&base, 2e-4),
        "engines disagree!\n{}",
        r.diagram.diff_summary(&base)
    );
    println!(
        "PDs agree | headline: dory {:.2}s / {} vs baseline {:.2}s / {} (mem ratio {:.1}x)",
        t_dory,
        memtrack::fmt_bytes(dory_peak),
        t_base,
        memtrack::fmt_bytes(base_peak),
        base_peak as f64 / dory_peak.max(1) as f64
    );

    // ---- L1/L2 via PJRT: persistence image --------------------------------
    if rt.has_pimage_kernel() {
        let pairs: Vec<(f32, f32, f32)> = r
            .diagram
            .finite(1)
            .iter()
            .map(|p| (p.birth as f32, (p.death - p.birth) as f32, 1.0))
            .collect();
        let (g, img) = rt.persistence_image(&pairs, tau as f32)?;
        println!("\npersistence image ({g}x{g}) of H1, via the Pallas kernel:");
        let mx = img.iter().cloned().fold(0.0f32, f32::max).max(1e-9);
        let shades = [' ', '.', ':', '+', '*', '#'];
        for row in (0..g).step_by((g / 16).max(1)) {
            let mut line = String::new();
            for col in (0..g).step_by((g / 32).max(1)) {
                let v = img[row * g + col] / mx;
                line.push(shades[((v * 5.0) as usize).min(5)]);
            }
            println!("  |{line}|");
        }
    }
    println!("\nE2E OK — all layers composed (recorded in EXPERIMENTS.md).");
    Ok(())
}
