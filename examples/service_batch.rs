//! Service mode: one ingest, a batch of τ-queries, typed errors.
//!
//!     cargo run --release --example service_batch
//!
//! The session API is the seam the "heavy traffic" deployment plugs
//! into: a `Session` owns the persistent engine + worker pool, ingests
//! a dataset **once** (pooled distance tiles + key sort + CSR build),
//! and serves every subsequent threshold query from the shared sorted
//! edge set — sub-τ queries prefix-truncate, nothing is rebuilt, and
//! diagrams are bit-identical to cold one-shot runs. This example
//! measures that amortization directly and then walks the typed error
//! surface a server would branch on.

use dory::datasets;
use dory::error::DoryError;
use dory::homology::{compute_ph, EngineOptions, PhRequest, Session};

fn main() -> Result<(), DoryError> {
    let n = 700usize;
    let data = datasets::sphere(n, 1.0, 0.0, 11);
    let taus = [0.15, 0.2, 0.25, 0.3, 0.35, 0.4, 0.45, 0.5];
    let opts = EngineOptions {
        max_dim: 1,
        threads: 4,
        ..Default::default()
    };

    // ---- one ingest, eight queries ----------------------------------
    let session = Session::new(opts.clone());
    let t0 = std::time::Instant::now();
    let handle = session.ingest(&data, 0.5)?;
    let t_ingest = t0.elapsed().as_secs_f64();
    println!(
        "ingest: n={} -> {} edges in {:.3}s (the only F1/CSR build this run)",
        handle.n_points(),
        handle.n_edges(),
        t_ingest
    );

    let reqs: Vec<PhRequest> = taus
        .iter()
        .map(|&tau| PhRequest {
            tau,
            label: Some(format!("tau={tau}")),
            ..Default::default()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let responses = session.run_batch(&handle, &reqs)?;
    let t_batch = t_ingest + t0.elapsed().as_secs_f64();
    println!("\n  {:<10} {:>8} {:>6} {:>9}", "query", "edges", "H1", "served");
    for r in &responses {
        println!(
            "  {:<10} {:>8} {:>6} {:>9}",
            r.label.as_deref().unwrap_or("-"),
            r.n_edges,
            r.result.diagram.betti_at(1, r.tau * 0.9),
            if r.truncated { "prefix" } else { "full" },
        );
    }
    let st = session.stats();
    println!(
        "\nsession counters: {} queries, {} F1 builds, {} CSR builds (amortized!)",
        st.queries, st.filtration_builds, st.nb_builds
    );

    // ---- the same eight answers, cold -------------------------------
    let t0 = std::time::Instant::now();
    for (&tau, resp) in taus.iter().zip(&responses) {
        let cold = compute_ph(&data, tau, &opts);
        assert!(
            cold.diagram.multiset_eq(&resp.result.diagram, 0.0),
            "session answers must be bit-identical to cold runs"
        );
    }
    let t_cold = t0.elapsed().as_secs_f64();
    println!(
        "batch-of-{} on one ingest: {:.3}s | {} cold runs: {:.3}s | amortization x{:.2}",
        taus.len(),
        t_batch,
        taus.len(),
        t_cold,
        t_cold / t_batch
    );

    // ---- the same queries, concurrently -----------------------------
    // Every session entry point takes `&self`: scoped threads fire the
    // whole batch at once against the one handle, the shared pool
    // interleaves the queries' task generations fairly, and each answer
    // is still bit-identical to its serial counterpart.
    let t0 = std::time::Instant::now();
    let concurrent: Vec<_> = std::thread::scope(|scope| {
        let joins: Vec<_> = taus
            .iter()
            .map(|&tau| {
                let session = &session;
                let handle = &handle;
                scope.spawn(move || session.query(handle, &PhRequest::at(tau)))
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let t_conc = t0.elapsed().as_secs_f64();
    for (conc, serial) in concurrent.iter().zip(&responses) {
        let conc = conc.as_ref().expect("concurrent query");
        assert!(
            conc.result.diagram.multiset_eq(&serial.result.diagram, 0.0),
            "concurrent answers must be bit-identical to serial ones"
        );
    }
    println!(
        "{} concurrent queries on one handle: {:.3}s (serial batch was {:.3}s) — same bits",
        taus.len(),
        t_conc,
        t_batch - t_ingest
    );

    // ---- the typed error surface ------------------------------------
    println!("\ntyped errors:");
    match session.query(&handle, &PhRequest::at(0.75)) {
        Err(DoryError::TauExceedsIngest {
            requested,
            ingested,
        }) => println!("  tau {requested} > ingest {ingested}: TauExceedsIngest (re-ingest to serve)"),
        other => panic!("expected TauExceedsIngest, got {:?}", other.err()),
    }
    let nan = dory::geometry::MetricData::Points(dory::geometry::PointCloud::new(
        2,
        vec![0.0, 0.0, f64::NAN, 1.0],
    ));
    match session.ingest(&nan, 1.0) {
        Err(e @ DoryError::InvalidInput(_)) => println!("  NaN ingest: {e}"),
        other => panic!("expected InvalidInput, got {:?}", other.err()),
    }
    // NaN or negative τ would silently serve an empty diagram (every
    // `v <= tau` comparison false); both are refused up front instead.
    match session.query(&handle, &PhRequest::at(-0.5)) {
        Err(e @ DoryError::Request(_)) => println!("  negative-tau query: {e}"),
        other => panic!("expected Request, got {:?}", other.err()),
    }
    match session.query(&handle, &PhRequest::at(f64::NAN)) {
        Err(e @ DoryError::Request(_)) => println!("  NaN-tau query: {e}"),
        other => panic!("expected Request, got {:?}", other.err()),
    }
    // The session survives refused requests: serve one more query.
    let again = session.query(&handle, &PhRequest::at(0.3))?;
    println!(
        "  ...session still healthy: re-served tau=0.3 ({} edges)",
        again.n_edges
    );
    Ok(())
}
