//! Overload-shedding smoke: flood one tenant, watch it shed typed.
//!
//!     cargo run --release --example overload_flood
//!
//! The stdio front of `dory serve` answers one line at a time, so a
//! stdin transcript can never overload it — admission control exists
//! for embedders driving [`dory::serve::Server::handle_line`] from many
//! threads at once (the `&self` concurrent-serving model). This smoke
//! is that embedder: a server with a per-tenant quota of 1 (and a
//! global cap wide enough that the quota is the binding constraint)
//! takes a barrier-synchronized flood of 160 queries from one tenant
//! while a second tenant keeps issuing single queries. It exits
//! nonzero unless
//!
//! * every refused request carried a typed `Overloaded` wire error
//!   (never a panic, a hang, or a mis-kinded error),
//! * the flooding tenant still got real answers (shedding bounds
//!   concurrency, it does not blocklist),
//! * the calm tenant completed every query — one tenant's flood must
//!   not starve another inside the shared admission gate, and
//! * the summary trailer's `resilience` block accounts for every shed
//!   (plus the retry/degradation counters a fleet scraper would watch).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;

use dory::homology::EngineOptions;
use dory::serve::Server;
use dory::util::json::Json;

const FLOOD_THREADS: usize = 8;
const QUERIES_PER_THREAD: usize = 20;
const CALM_QUERIES: usize = 10;

fn main() {
    let srv = Server::new(
        EngineOptions {
            max_dim: 1,
            threads: 4,
            ..Default::default()
        },
        64 << 20,
    )
    // Per-tenant quota of 1 is what the flood races. The global cap
    // stays above flood-threads + calm so a transient global slot held
    // by a flood thread (taken before its quota refusal releases it)
    // can never shed the calm tenant — tenant isolation is the claim
    // under test, and it must hold deterministically.
    .with_overload(FLOOD_THREADS + 2, 1);

    // One shared ingest both tenants query (cache hits are un-gated, so
    // the flood below exercises the query path, not the build path).
    let (ingest, _) = srv.handle_line(
        r#"{"id":0,"tenant":"flood","method":"ingest","dataset":{"kind":"circle","n":64,"seed":7}}"#,
    );
    let key = ingest
        .get("ok")
        .and_then(|ok| ok.get("handle"))
        .and_then(|h| h.as_str())
        .expect("ingest must succeed")
        .to_string();

    let shed = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let calm_ok = AtomicU64::new(0);
    let barrier = Barrier::new(FLOOD_THREADS + 1);
    std::thread::scope(|scope| {
        for t in 0..FLOOD_THREADS {
            let (srv, key, barrier, shed, served) = (&srv, &key, &barrier, &shed, &served);
            scope.spawn(move || {
                barrier.wait();
                for q in 0..QUERIES_PER_THREAD {
                    let line = format!(
                        "{{\"id\":{},\"tenant\":\"flood\",\"method\":\"query\",\
                         \"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}",
                        1 + t * QUERIES_PER_THREAD + q
                    );
                    let (resp, _) = srv.handle_line(&line);
                    if resp.get("ok").is_some() {
                        served.fetch_add(1, Ordering::Relaxed);
                    } else {
                        let kind = resp
                            .get("error")
                            .and_then(|e| e.get("kind"))
                            .and_then(|k| k.as_str())
                            .unwrap_or("<missing>")
                            .to_string();
                        assert_eq!(
                            kind,
                            "Overloaded",
                            "a refused flood query must shed typed, got: {}",
                            resp.render()
                        );
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        // The calm tenant runs concurrently with the flood: its quota
        // slot is its own, so every one of its queries must succeed.
        let (srv, key, barrier, calm_ok) = (&srv, &key, &barrier, &calm_ok);
        scope.spawn(move || {
            barrier.wait();
            for q in 0..CALM_QUERIES {
                let line = format!(
                    "{{\"id\":{},\"tenant\":\"calm\",\"method\":\"query\",\
                     \"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}",
                    9000 + q
                );
                let (resp, _) = srv.handle_line(&line);
                assert!(
                    resp.get("ok").is_some(),
                    "the calm tenant must never be starved by the flood: {}",
                    resp.render()
                );
                calm_ok.fetch_add(1, Ordering::Relaxed);
            }
        });
    });

    let shed = shed.load(Ordering::Relaxed);
    let served = served.load(Ordering::Relaxed);
    let calm_ok = calm_ok.load(Ordering::Relaxed);
    let total = (FLOOD_THREADS * QUERIES_PER_THREAD) as u64;
    assert_eq!(served + shed, total, "every flood query was answered");
    // 8 threads racing a tenant quota of 1: overlap is a statistical
    // certainty at this scale. Both outcomes must occur.
    assert!(shed > 0, "the flood never tripped the gate — admission is inert");
    assert!(served > 0, "shedding must bound concurrency, not blocklist the tenant");
    assert_eq!(calm_ok as usize, CALM_QUERIES);

    let summary = srv.summary_json();
    let text = summary.render();
    let parsed = Json::parse(&text).expect("summary renders valid JSON");
    let rc = parsed.get("resilience").expect("summary carries a resilience block");
    let reported = rc.get("shed").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
    assert_eq!(reported, shed, "the trailer must account for every shed");
    for field in ["panics", "write_retries", "degraded_ingests", "ingest_io_retries"] {
        assert!(rc.get(field).is_some(), "resilience block is missing '{field}'");
    }

    println!(
        "overload flood: {served} served + {shed} shed (typed) of {total} from one tenant; \
         calm tenant {calm_ok}/{CALM_QUERIES} ok; trailer shed={reported}"
    );
}
