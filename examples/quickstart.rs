//! Quickstart: compute the persistent homology of a small point cloud.
//!
//!     cargo run --release --example quickstart
//!
//! Reproduces the paper's Figure 1/4 story: a multi-scale data set whose
//! PD shows two small loops and one large one, at different scales —
//! served through the session API, whose whole point is multi-scale
//! exploration: ingest once, then query several thresholds from the
//! same sorted edge set.

use dory::datasets;
use dory::error::DoryError;
use dory::homology::{EngineOptions, PhRequest, Session};

fn main() -> Result<(), DoryError> {
    // 1. Data: two small circles + one large annulus (paper Fig. 1).
    let data = datasets::multi_scale_demo(600, 7);

    // 2. A session with the default engine (fast implicit column) and
    //    one ingest at τ = 8, covering all three features' deaths.
    let session = Session::new(EngineOptions {
        max_dim: 1,
        threads: 2,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let handle = session.ingest(&data, 8.0)?;
    let r = session.query(&handle, &PhRequest::at(8.0))?.result;
    println!(
        "n={} edges={} in {:.2}s  ({})",
        r.stats.n,
        r.stats.n_edges,
        t0.elapsed().as_secs_f64(),
        r.timings.summary()
    );

    // 3. Read the diagram.
    println!(
        "\nH0: {} components merge, {} essential",
        r.diagram.finite(0).len(),
        r.diagram.essential_count(0)
    );
    let mut h1 = r.diagram.points(1).to_vec();
    h1.sort_by(|a, b| b.persistence().partial_cmp(&a.persistence()).unwrap());
    println!(
        "H1: {} classes; the {} most persistent:",
        h1.len(),
        5.min(h1.len())
    );
    for p in h1.iter().take(5) {
        let bar = "#".repeat((p.persistence().min(8.0) * 6.0) as usize);
        if p.is_essential() {
            println!("  birth {:6.3}  death    inf  {bar}>", p.birth);
        } else {
            println!("  birth {:6.3}  death {:6.3}  {bar}", p.birth, p.death);
        }
    }
    println!("\nExpected: two mid-persistence loops (the small circles, dying");
    println!("around 2.5·√3 ≈ 4.3) and one large/essential loop (the annulus).");

    // 4. The multi-scale zoom, free of charge: sub-τ queries reuse the
    //    ingest (prefix truncation — no distances recomputed).
    println!("\nzoom (same ingest, no rebuild):");
    for tau in [2.0, 5.0] {
        let zoom = session.query(&handle, &PhRequest::at(tau))?;
        println!(
            "  tau={tau}: {} edges, {} H1 classes alive at {:.1}",
            zoom.n_edges,
            zoom.result.diagram.betti_at(1, tau * 0.9),
            tau * 0.9,
        );
    }
    let st = session.stats();
    println!(
        "session: {} queries, {} filtration build (amortized)",
        st.queries, st.filtration_builds
    );
    Ok(())
}
